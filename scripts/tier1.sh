#!/usr/bin/env bash
# Tier-1 (fast) test suite — the CI gate every PR must keep green.
#
#   scripts/tier1.sh            # == JAX_PLATFORMS=cpu PYTHONPATH=src pytest -x -q
#   scripts/tier1.sh --fast     # skip slow AND pallas interpret-mode kernels
#                               # (slow = statistical sweeps, pool-invariant
#                               # rerolls, long open-loop traffic replays)
#   scripts/tier1.sh --stress   # randomized pool/radix/COW invariant suite:
#                               # the fixed tier-1 seed PLUS the reroll seeds
#                               # (marked `slow`, see tests/test_pool_invariants.py)
#   scripts/tier1.sh --pallas   # the pallas-marked interpret-mode kernel
#                               # tests (ref-oracle sweeps incl. the rolling
#                               # non-aligned-capacity regression + the
#                               # attn_impl gather-vs-pallas token-parity
#                               # gate, sliding-window hybrid included) PLUS
#                               # the 8-device sharded read-path parity
#                               # subprocess tests (sharded pallas engine +
#                               # sharded drafter reads) — the complement of
#                               # --fast's "not pallas"
#   scripts/tier1.sh --mesh     # re-run the suite on an 8-device host mesh
#                               # (XLA_FLAGS=--xla_force_host_platform_device_count=8,
#                               # REPRO_MESH=1x4: every test wrapped in a
#                               # use_sharding kv_seq context — the sharded
#                               # resident-serving gate; combines with --fast:
#                               # `scripts/tier1.sh --mesh --fast`)
#   scripts/tier1.sh tests/test_paged.py   # extra args pass through
#
# Pallas kernels run in interpret mode on CPU (pytest marker `pallas`);
# the full suite including slow statistical sweeps is
#   scripts/tier1.sh -m "slow or not slow"
set -euo pipefail
cd "$(dirname "$0")/.."
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
if [[ "${1:-}" == "--mesh" ]]; then
  shift
  export XLA_FLAGS="${XLA_FLAGS:---xla_force_host_platform_device_count=8}"
  export REPRO_MESH="${REPRO_MESH:-1x4}"
fi
if [[ "${1:-}" == "--fast" ]]; then
  shift
  exec python -m pytest -x -q -m "not slow and not pallas" "$@"
fi
if [[ "${1:-}" == "--stress" ]]; then
  shift
  exec python -m pytest -x -q tests/test_pool_invariants.py \
    -m "slow or not slow" "$@"
fi
if [[ "${1:-}" == "--pallas" ]]; then
  shift
  # the sharded read-path parity tests live in test_sharded_serving.py
  # (subprocess 8-device meshes, not pallas-marked — they cover BOTH
  # read_impls): select them alongside the pallas marker sweeps
  python -m pytest -x -q -m pallas "$@"
  exec python -m pytest -x -q tests/test_sharded_serving.py \
    -k "pallas_read_path or drafter_read"
fi
exec python -m pytest -x -q "$@"
