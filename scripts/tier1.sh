#!/usr/bin/env bash
# Tier-1 (fast) test suite — the CI gate every PR must keep green.
#
#   scripts/tier1.sh            # == JAX_PLATFORMS=cpu PYTHONPATH=src pytest -x -q
#   scripts/tier1.sh --fast     # skip slow AND pallas interpret-mode kernels
#                               # (slow = statistical sweeps, pool-invariant
#                               # rerolls, long open-loop traffic replays)
#   scripts/tier1.sh --stress   # randomized pool/radix/COW invariant suite:
#                               # the fixed tier-1 seed PLUS the reroll seeds
#                               # (marked `slow`, see tests/test_pool_invariants.py)
#   scripts/tier1.sh tests/test_paged.py   # extra args pass through
#
# Pallas kernels run in interpret mode on CPU (pytest marker `pallas`);
# the full suite including slow statistical sweeps is
#   scripts/tier1.sh -m "slow or not slow"
set -euo pipefail
cd "$(dirname "$0")/.."
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
if [[ "${1:-}" == "--fast" ]]; then
  shift
  exec python -m pytest -x -q -m "not slow and not pallas" "$@"
fi
if [[ "${1:-}" == "--stress" ]]; then
  shift
  exec python -m pytest -x -q tests/test_pool_invariants.py \
    -m "slow or not slow" "$@"
fi
exec python -m pytest -x -q "$@"
