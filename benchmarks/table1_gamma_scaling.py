"""Paper Table 1: DFlash acceptance (TPF) vs block size gamma — the
"scaling wall". The drafter is trained at gamma=16; gammas <= 16 evaluate
truncated blocks (the paper retrains per gamma with decay-matched schedules
— our single-checkpoint evaluation is the documented deviation)."""
from __future__ import annotations

from benchmarks.common import csv_row, measure


def run(quick: bool = False):
    rows = []
    gammas = [4, 8, 12, 16] if not quick else [4, 16]
    tasks = ["math", "code"] if not quick else ["math"]
    print("# Table 1 — DFlash TPF vs gamma (scaling wall)")
    print("gamma," + ",".join(f"{t}_tpf" for t in tasks))
    for g in gammas:
        vals = []
        for t in tasks:
            r = measure("dflash", t, gamma=g,
                        n_prompts=6 if quick else 12,
                        max_new=48 if quick else 96)
            vals.append(r.alpha)
        print(f"{g}," + ",".join(f"{v:.2f}" for v in vals))
        rows.append((g, vals))
    return rows


if __name__ == "__main__":
    run()
