"""Shared benchmark machinery: load study artifacts, build bundles per
method, measure acceptance (TPF/alpha), model wall-clock speedup on the
TPU-v5e roofline."""
from __future__ import annotations

import dataclasses
import json
import sys
import time
from pathlib import Path
from typing import Dict, List, Optional

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.config.base import SpecConfig
from repro.core import pipeline as pl
from repro.core import strategies
from repro.data.synthetic import SyntheticDataset, TASKS
from repro.training.run_study import load_study

# ------------------------------------------------------ latency model ------
# TPU v5e per chip; decode is memory-bound: a pass costs ~bytes/BW.
PEAK = 197e12
HBM_BW = 819e9

# paper-scale reference model (Qwen3-8B-like, bf16) for the speedup model
TARGET_BYTES = 8.2e9 * 2
DRAFTER_BYTES = 0.35e9 * 2          # DFlash-style lightweight drafter


def modeled_latency(n_tokens: int, params_bytes: float,
                    extra_flops: float = 0.0) -> float:
    """One forward pass over n_tokens with a KV-cache read folded into a
    20% overhead (32k ctx): max(memory, compute)."""
    mem = params_bytes * 1.2 / HBM_BW
    comp = 2 * (params_bytes / 2) * n_tokens / PEAK + extra_flops / PEAK
    return max(mem, comp)


def modeled_speedup(alpha: float, n_draft_passes: int, tree_size: int,
                    ar_baseline: Optional[float] = None) -> float:
    """Paper Eq. 2: eta = alpha * L_target / (T_draft + T_verify)."""
    l_target = modeled_latency(1, TARGET_BYTES)
    t_draft = n_draft_passes * modeled_latency(16, DRAFTER_BYTES)
    t_verify = modeled_latency(tree_size, TARGET_BYTES)
    return alpha * l_target / (t_draft + t_verify)


# ------------------------------------------------------------ measuring ----
@dataclasses.dataclass
class MethodResult:
    alpha: float                    # mean accepted tokens / cycle (TPF)
    speedup: float                  # modeled on the roofline (paper scale)
    wall_tokens_per_s: float        # measured CPU wall (small scale)
    conf: Optional[np.ndarray] = None
    trunk_ok: Optional[np.ndarray] = None


_STUDY = None


def study():
    global _STUDY
    if _STUDY is None:
        _STUDY = load_study()
    return _STUDY


def build_bundle(method: str, gamma: int = None, k: int = 4,
                 temperature: float = 0.0) -> pl.SpecBundle:
    tcfg, dcfg, dcfg_ar, params, meta = study()
    g = gamma or meta["gamma"]
    mode = {"d2sd": "d2sd", "dflash": "dflash", "naive_k": "naive_k",
            "dflash_second": "dflash_second", "eagle": "eagle",
            "d2sd_l3": "d2sd"}[method]
    spec = SpecConfig(gamma=g, top_k_branches=k, mode=mode,
                      temperature=temperature,
                      third_level=(method == "d2sd_l3"))
    import dataclasses as dc
    d1cfg = dcfg_ar if method == "eagle" else dc.replace(dcfg, gamma=g)
    d1 = params["ar"] if method == "eagle" else params["d1"]
    d2 = params["d1"] if method in ("dflash_second", "naive_k") \
        else params["d2"]
    return pl.SpecBundle(tcfg, d1cfg, dc.replace(dcfg, gamma=g), spec,
                         params["target"], d1, d2)


def _method_spec(method: str, gamma: int, k: int) -> SpecConfig:
    mode = "d2sd" if method == "d2sd_l3" else method
    return SpecConfig(gamma=gamma, top_k_branches=k, mode=mode,
                      third_level=(method == "d2sd_l3"))


def n_draft_passes(method: str, gamma: int, k: int = 4) -> int:
    spec = _method_spec(method, gamma, k)
    return strategies.get_strategy(spec.mode).n_draft_passes(spec)


def tree_size(method: str, gamma: int, k: int) -> int:
    spec = _method_spec(method, gamma, k)
    return strategies.get_strategy(spec.mode).n_tree_nodes(spec)


def measure(method: str, task: str, *, n_prompts: int = 12,
            prompt_len: int = 48, max_new: int = 96, gamma: int = None,
            k: int = 4, temperature: float = 0.0,
            seed: int = 0) -> MethodResult:
    bundle = build_bundle(method, gamma=gamma, k=k, temperature=temperature)
    g = bundle.spec.gamma
    ds = SyntheticDataset(task, 1, 64, seed=777 + seed)
    prompts = ds.prompts(n_prompts, prompt_len, offset=5 * 10 ** 6)
    t0 = time.time()
    out = pl.generate(bundle, prompts, max_new=max_new,
                      key=jax.random.PRNGKey(seed), collect_stats=True)
    dt = time.time() - t0
    alpha = out["alpha"]
    sp = modeled_speedup(alpha, n_draft_passes(method, g),
                         tree_size(method, g, k))
    conf = (np.concatenate([c.reshape(-1) for c in out["stats"]["conf"]])
            if out["stats"]["conf"] else None)
    tok = (np.concatenate([c.reshape(-1) for c in out["stats"]["trunk_ok"]])
           if out["stats"]["trunk_ok"] else None)
    return MethodResult(alpha=alpha, speedup=sp,
                        wall_tokens_per_s=n_prompts * max_new / dt,
                        conf=conf, trunk_ok=tok)


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


def merge_bench_json(path, section: str, payload: dict) -> None:
    """Update one section of a BENCH_*.json file, keeping the others."""
    p = Path(path)
    data = {}
    if p.exists():
        try:
            data = json.loads(p.read_text())
        except ValueError:
            data = {}
    data[section] = payload
    p.write_text(json.dumps(data, indent=2, default=float))
    print(f"wrote {p} [{section}]")
