"""Paper Fig. 2a: drafter confidence vs empirical accept rate — the
calibration property that justifies Eq. 4's boundary posterior."""
from __future__ import annotations

import numpy as np

from benchmarks.common import measure


def run(quick: bool = False):
    r = measure("dflash", "math", n_prompts=6 if quick else 16,
                max_new=64 if quick else 128)
    conf, ok = r.conf, r.trunk_ok
    assert conf is not None and ok is not None
    bins = np.linspace(0, 1, 11)
    idx = np.clip(np.digitize(conf, bins) - 1, 0, 9)
    print("# Fig 2a — confidence bin vs empirical accept rate")
    print("bin_lo,bin_hi,n,accept_rate")
    rows = []
    for b in range(10):
        m = idx == b
        if m.sum() == 0:
            continue
        rate = float(ok[m].mean())
        print(f"{bins[b]:.1f},{bins[b + 1]:.1f},{int(m.sum())},{rate:.3f}")
        rows.append((bins[b], rate, int(m.sum())))
    # calibration error (weighted)
    n_tot = sum(n for _, _, n in rows)
    ece = sum(n * abs((lo + 0.05) - r_) for lo, r_, n in rows) / n_tot
    print(f"# expected calibration error ~ {ece:.3f}")
    return rows


if __name__ == "__main__":
    run()
