"""Paper Tables 3/4 analogue: wall-clock speedup (roofline-modeled at paper
scale) + mean acceptance length for DFlash / EAGLE-style AR / D2SD across
task categories, greedy and T=1."""
from __future__ import annotations

from benchmarks.common import measure


METHODS = ["dflash", "eagle", "d2sd"]


def run(quick: bool = False, temps=(0.0, 1.0)):
    tasks = ["math", "code", "chat"] if not quick else ["math", "chat"]
    out = {}
    for temp in temps:
        print(f"# Table 3 — speedup x / acceptance alpha (T={temp:g})")
        print("task," + ",".join(f"{m}_speedup,{m}_alpha" for m in METHODS))
        for task in tasks:
            cells = []
            for m in METHODS:
                r = measure(m, task, temperature=temp,
                            n_prompts=4 if quick else 10,
                            max_new=48 if quick else 96)
                cells.append((r.speedup, r.alpha))
                out[(temp, task, m)] = r
            print(f"{task}," + ",".join(
                f"{s:.2f},{a:.2f}" for s, a in cells))
        avg = {m: (sum(out[(temp, t, m)].speedup for t in tasks) / len(tasks),
                   sum(out[(temp, t, m)].alpha for t in tasks) / len(tasks))
               for m in METHODS}
        print("average," + ",".join(
            f"{avg[m][0]:.2f},{avg[m][1]:.2f}" for m in METHODS))
    return out


if __name__ == "__main__":
    run()
