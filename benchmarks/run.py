"""Benchmark harness entry point — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only tableX]

Prints ``name,us_per_call,derived`` CSV blocks per section. Requires the
study artifacts (experiments/study) — run
``PYTHONPATH=src python -m repro.training.run_study`` first; falls back to
--quick-compatible behavior with a helpful error otherwise.
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--suite", default=None,
                    help="run a single section by name (alias of --only), "
                         "e.g. --suite serving -> BENCH_serving.json")
    ap.add_argument("--skip-study", action="store_true",
                    help="only run benches that need no trained artifacts")
    args = ap.parse_args()
    args.only = args.only or args.suite

    from benchmarks import engine_bench, kernel_bench, serving_bench
    sections = [("kernels", lambda q: kernel_bench.run(q)),
                ("engine", lambda q: engine_bench.run(q)),
                ("serving", lambda q: serving_bench.run(q)),
                ("prefix", lambda q: serving_bench.run_prefix(q)),
                ("resident", lambda q: serving_bench.run_resident(q)),
                ("sla", lambda q: serving_bench.run_sla(q)),
                ("bytes", lambda q: serving_bench.run_bytes_model(q)),
                ("sharded", lambda q: serving_bench.run_sharded(q))]

    study_dir = Path(__file__).resolve().parents[1] / "experiments" / "study"
    if not args.skip_study:
        if not (study_dir / "meta.json").exists():
            print("!! study artifacts missing — run "
                  "`PYTHONPATH=src python -m repro.training.run_study` "
                  "first; running kernel section only.")
        else:
            from benchmarks import (fig2a_calibration, table1_gamma_scaling,
                                    table3_end_to_end, table5_naive_k,
                                    table6_dflash_second, table7_third_level)
            sections += [
                ("table1", lambda q: table1_gamma_scaling.run(q)),
                ("table3", lambda q: table3_end_to_end.run(
                    q, temps=(0.0,) if q else (0.0, 1.0))),
                ("table5", lambda q: table5_naive_k.run(q)),
                ("table6", lambda q: table6_dflash_second.run(q)),
                ("table7", lambda q: table7_third_level.run(q)),
                ("fig2a", lambda q: fig2a_calibration.run(q)),
            ]

    for name, fn in sections:
        if args.only and args.only != name:
            continue
        t0 = time.time()
        print(f"\n===== {name} =====")
        try:
            fn(args.quick)
        except Exception as e:  # noqa
            print(f"SECTION FAILED: {name}: {e!r}")
        print(f"===== {name} done ({time.time() - t0:.0f}s) =====")


if __name__ == "__main__":
    main()
