"""Serving micro-benchmark: batching policy AND KV storage A/B.

Replays the same mixed traffic (one long budget + sustained short
requests, mixed prompt lengths) through :class:`ServingEngine` three
times —

* ``legacy_waves``      — ``early_exit=False, refill=False``, dense KV
  (the drain-the-wave engine);
* ``early_exit_refill`` — both batching optimizations on, dense KV;
* ``paged``             — batching optimizations on, ``cache_impl="paged"``
  (page-pool KV storage, page-granular admission, copy-free refill);
* ``paged_pallas``      — the paged engine with ``attn_impl="pallas"``
  (cascade kernels read the pool + page table directly; interpret mode
  on CPU) — the kernelized read path, token-identical by assertion;

and reports tokens/s, ``warm_cycle_s`` (median post-warmup per-cycle
time — ``wall_s`` is trace/compile-dominated at tiny scale),
``wasted_row_cycles`` (batch rows that spent a
decode cycle without a live, unfinished request), pool utilization, and
``refill_copy_bytes`` — the accounting model of bytes each slot install
writes (dense: a full ``max_len`` row per cache; paged: prompt-sized
tail-page writes + one page-table row). Per-request token output is
asserted identical across ALL configurations (greedy decoding, per-row
isolation, exact logical-view equivalence of the paged layout), so the
deltas are pure batching / memory-subsystem efficiency. Results land in
``BENCH_serving.json`` at the repo root.

``--suite prefix`` replays shared-prefix traffic (a shared-system-prompt
fleet plus multi-turn follow-ups whose prompts extend turn-1's
prompt+answer) through the paged engine with the radix prefix cache OFF
and ON: per-request tokens are asserted identical, and the hit-rate
metrics (``prefix_hits`` / ``prefill_tokens_saved`` / ``cow_copies`` /
``prefix_evictions``) land in the ``prefix`` section of the same JSON.

``--suite resident`` replays a RESIDENT-server schedule: the engine stays
alive across several submit→drain rounds (each round is a wave turnover),
and round N+1's prompts extend round N's committed strings. With the
engine-lifetime pool (``pool_scope="engine"``, the default) the radix
tree survives the turnover, so the later rounds hit prefixes cached in
EARLIER waves (``prefix_hit_tokens`` grows after the first turnover —
asserted); per-request tokens are asserted identical cache-on vs
cache-off vs legacy per-wave pools. Results land in the ``resident``
section.

``--suite sharded`` replays the resident burst schedule through ONE
engine spanning a 4-way ``kv_seq`` host mesh (page payload bytes
sharded within-page, page identity host-global, cascade verify under
``shard_map``) vs the single-device engine: per-request tokens are
asserted identical, cross-wave prefix hits must survive turnover
through the sharded pool, and the ``sharded`` section reports the
per-shard pool placement (``pool_shard_slots``, utilization) and the
``decode_collective_bytes`` the verify LSE-psum moves. Re-execs itself
under ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` when the
host exposes fewer than 4 devices.

``--suite bytes`` emits the ``bytes_model`` section: analytic
bytes-moved-per-decode-cycle for the gather vs pallas read paths
(``roofline/bytes_model.py``) swept over live length and capacity, plus
gather/dynamic-slice byte attribution of the actual compiled decode
cycle (``roofline/hlo_analysis.py``). Asserts the scaling claim: kernel
bytes grow with LIVE cache length, gather bytes with CAPACITY.

Needs no trained study artifacts — builds a tiny random bundle. The
bundle uses a SMALL vocab (17): with random-init drafters the chance a
draft token matches the target argmax scales as ~1/vocab, and the
original vocab-199 bundle produced the degenerate ``accepted == 0`` /
``alpha == 1.0`` in every config — the stats pipeline was real but the
workload couldn't exercise it. vocab=17 yields genuine multi-token
acceptance (asserted), so ``alpha`` / ``accepted`` now measure the
verify backends' real output.

    PYTHONPATH=src python -m benchmarks.run --suite serving [--quick]
    PYTHONPATH=src python -m benchmarks.run --suite prefix  [--quick]
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from benchmarks.common import csv_row, merge_bench_json
from benchmarks.engine_bench import _tiny_bundle
from repro.serving.engine import ServingEngine

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_serving.json"
PAGE_SIZE = 16
VOCAB = 17          # small on purpose: real acceptance from random drafters


def _traffic(vocab: int, quick: bool):
    """One long request up front + sustained short/mid traffic."""
    rng = np.random.default_rng(0)
    if quick:
        budgets = [20, 4, 6, 4, 5, 4]
        plens = [10, 8, 9, 8, 11, 8]
    else:
        budgets = [32, 6, 8, 5, 10, 6, 7, 5, 9, 6, 8, 5]
        plens = [14, 8, 10, 9, 12, 8, 11, 9, 10, 8, 9, 12]
    return [(rng.integers(3, vocab, size=p).astype(np.int32), n)
            for p, n in zip(plens, budgets)]


def _merge_bench_json(section: str, payload: dict) -> None:
    """Update one section of BENCH_serving.json, keeping the others."""
    merge_bench_json(BENCH_PATH, section, payload)


def _serve(bundle, reqs, batch: int, early_exit: bool = True,
           refill: bool = True, cache_impl: str = "dense", **kw):
    eng = ServingEngine(bundle, batch_size=batch, seed=0,
                        early_exit=early_exit, refill=refill,
                        cache_impl=cache_impl, page_size=PAGE_SIZE, **kw)
    for p, n in reqs:
        eng.submit(p, max_new=n)
    stats = eng.run()
    outs = {r.uid: r.out.tolist() for r in eng.done}
    return stats, outs


def _row(name, s):
    extra = ""
    if s.get("pool_pages"):
        extra = (f" pool_util={s['pool_utilization']:.2f} "
                 f"pool_peak={s['pool_peak_pages']}/{s['pool_pages']}")
    if s.get("prefix_hits"):
        extra += (f" prefix_hits={s['prefix_hits']} "
                  f"saved_tokens={s['prefill_tokens_saved']} "
                  f"cow={s['cow_copies']}")
    print(csv_row(
        name, s["wall_s"] * 1e6,
        f"tokens_per_s={s['tokens_per_s']:.1f} "
        f"warm_cycle_s={s.get('warm_cycle_s', 0.0):.4f} "
        f"wasted_row_cycles={s['wasted_row_cycles']} "
        f"alpha={s['alpha']:.3f} accepted={s['accepted']} "
        f"waves={s['waves']} refills={s['refills']} "
        f"refill_copy_bytes={s['refill_copy_bytes']}" + extra))


def run(quick: bool = False) -> None:
    gamma, k = (4, 2) if quick else (6, 2)
    batch = 2 if quick else 3
    bundle = _tiny_bundle(gamma, k, vocab=VOCAB)
    reqs = _traffic(bundle.target_cfg.vocab_size, quick)

    from repro.core import pipeline as pl

    base, base_out = _serve(bundle, reqs, batch, early_exit=False,
                            refill=False)
    opt, opt_out = _serve(bundle, reqs, batch)
    pgd, pgd_out = _serve(bundle, reqs, batch, cache_impl="paged")
    # kernelized read path A/B: same paged engine, attn_impl="pallas"
    # (interpret mode on CPU) — must be token-identical to the gather path
    pal, pal_out = _serve(pl.with_attn_impl(bundle, "pallas"), reqs, batch,
                          cache_impl="paged")
    tokens_equal = base_out == opt_out == pgd_out == pal_out
    assert tokens_equal, "batching/storage config changed per-request output"
    # real acceptance statistics, wired from the verify backends' n_acc
    # (vocab=17 guarantees the random bundle accepts some draft tokens)
    for s in (base, opt, pgd, pal):
        assert s["accepted"] > 0 and s["alpha"] > 1.0, (
            "degenerate acceptance stats", s["accepted"], s["alpha"])
    # copy-free refill acceptance: paged installs write page-order bytes
    assert pgd["installs"] == opt["installs"]
    assert pgd["refill_copy_bytes"] * 2 < opt["refill_copy_bytes"], (
        pgd["refill_copy_bytes"], opt["refill_copy_bytes"])

    _row("serving_legacy_waves", base)
    _row("serving_early_exit_refill", opt)
    _row("serving_paged_kv", pgd)
    _row("serving_paged_kv_pallas", pal)
    saved = base["wasted_row_cycles"] - opt["wasted_row_cycles"]
    copy_ratio = (opt["refill_copy_bytes"] / pgd["refill_copy_bytes"]
                  if pgd["refill_copy_bytes"] else float("inf"))
    print(csv_row("serving_wasted_cycle_reduction", 0.0,
                  f"saved={saved} tokens_equal={tokens_equal}"))
    print(csv_row("serving_refill_copy_reduction", 0.0,
                  f"dense/paged={copy_ratio:.1f}x"))

    _merge_bench_json("serving", {
        "config": {"gamma": gamma, "k": k, "batch": batch,
                   "n_requests": len(reqs), "quick": quick,
                   "page_size": PAGE_SIZE, "vocab": VOCAB},
        "legacy_waves": dict(base),
        "early_exit_refill": dict(opt),
        "paged": dict(pgd),
        "paged_pallas": dict(pal),
        "tokens_equal": tokens_equal,
        "wasted_row_cycles_saved": saved,
        "refill_copy_bytes_dense_over_paged": copy_ratio,
    })


# -------------------------------------------------------------- sla suite --
def _sla_replay(bundle, trace, overlap, batch, pool_pages):
    """One open-loop replay on a fresh engine + deterministic clock."""
    from repro.serving.frontend import ReplayDriver
    from repro.serving.metrics import MetricsRecorder, VirtualClock
    clock = VirtualClock(cycle_s=1.0, install_s=0.25)
    rec = MetricsRecorder(clock)
    eng = ServingEngine(bundle, batch_size=batch, seed=0,
                        cache_impl="paged", page_size=PAGE_SIZE,
                        pool_pages=pool_pages, clock=clock, recorder=rec)
    stats = ReplayDriver(eng, trace, overlap=overlap).run()
    outs = {r.uid: r.out.tolist() for r in eng.done}
    return stats, outs, rec


def run_sla(quick: bool = False) -> None:
    """Open-loop SLA suite: overlapped front-end vs synchronous baseline.

    Replays seeded poisson + bursty arrival traces
    (:mod:`repro.serving.traffic`) through the paged engine twice — the
    overlapped front-end (mid-flight admission during the decode overlap
    window) and the synchronous baseline (refill only at retire moments)
    — on a shared deterministic :class:`VirtualClock`. Asserts
    per-request token identity on BOTH traces, a strict engine-cycle win
    for the overlapped driver on the bursty trace (burst clumps land
    mid-wave; the sync engine leaves idle slots idle until a retire
    happens), and batched same-bucket installs
    (``install_calls < installs``). Per-request TTFT/TPOT/e2e and
    p50/p90/p99 summaries land in the ``sla`` section of
    ``BENCH_serving.json``.
    """
    from repro.serving import traffic
    bundle = _tiny_bundle(6, 2, vocab=VOCAB)
    batch, pool_pages = 4, 48
    dur = 16.0 if quick else 40.0
    # uniform prompt length on purpose: requests then differ only in
    # decode budget, so a long-anchored wave can always admit a queued
    # request of either budget class (no head-of-line size blocking) and
    # the suite measures SCHEDULING, not wave-sizing luck
    shape = dict(prompt_lens=(8,), max_new=(4, 40), vocab=VOCAB)
    legs = {}
    for kind, trace in [
        ("poisson", traffic.poisson_trace(rate=0.8, duration=dur,
                                          seed=0, **shape)),
        ("bursty", traffic.bursty_trace(rate=1.0, duration=dur, seed=3,
                                        calm_scale=0.3, burst_scale=5.0,
                                        mean_dwell=5.0, **shape)),
    ]:
        ov, ov_out, rec = _sla_replay(bundle, trace, True, batch,
                                      pool_pages)
        sy, sy_out, _ = _sla_replay(bundle, trace, False, batch,
                                    pool_pages)
        assert ov_out == sy_out, \
            f"{kind}: overlapped admission changed per-request output"
        assert len(ov_out) == len(trace)
        legs[kind] = {
            "n_requests": len(trace),
            "overlapped": dict(ov), "sync": dict(sy),
            "per_request": rec.per_request(),
            "tokens_equal": True,
            "cycle_win": sy["engine_cycles"] - ov["engine_cycles"],
        }
        for name, s in (("overlapped", ov), ("sync", sy)):
            t = s["sla"]["ttft"]
            print(csv_row(
                f"sla_{kind}_{name}", s["sla"]["e2e"]["p99"] * 1e6,
                f"cycles={s['engine_cycles']} "
                f"ttft_p50={t['p50']:.1f}s ttft_p99={t['p99']:.1f}s "
                f"tpot_p50={s['sla']['tpot']['p50']:.2f}s "
                f"queue_max={s['sla']['queue_depth']['max']}"))
    # the headline assertions: the overlapped front-end finishes the
    # bursty workload in strictly fewer engine cycles, and same-bucket
    # admissions actually collapsed into batched installs
    burst = legs["bursty"]
    assert burst["cycle_win"] > 0, (
        "overlapped front-end showed no cycle win on bursty traffic",
        burst["overlapped"]["engine_cycles"],
        burst["sync"]["engine_cycles"])
    ov = burst["overlapped"]
    assert ov["install_calls"] < ov["installs"], (
        "no same-bucket admissions were batched", ov["install_calls"],
        ov["installs"])
    print(csv_row("sla_bursty_cycle_win", 0.0,
                  f"sync={burst['sync']['engine_cycles']} "
                  f"overlapped={ov['engine_cycles']} "
                  f"win={burst['cycle_win']} "
                  f"batched_installs={ov['installs'] - ov['install_calls']}"))

    _merge_bench_json("sla", {
        "config": {"batch": batch, "pool_pages": pool_pages,
                   "duration_s": dur, "quick": quick,
                   "page_size": PAGE_SIZE, "vocab": VOCAB,
                   "clock": {"cycle_s": 1.0, "install_s": 0.25},
                   "trace_shape": {k: list(v) for k, v in shape.items()
                                   if k != "vocab"}},
        **legs,
    })
    # schema gate: downstream consumers read these exact keys — fail the
    # suite (not the reader) if the emitted shape drifts
    data = json.loads(BENCH_PATH.read_text())["sla"]
    for kind in ("poisson", "bursty"):
        leg = data[kind]
        assert leg["n_requests"] > 0 and leg["tokens_equal"]
        assert leg["per_request"], "empty per-request SLA list"
        for row in leg["per_request"]:
            assert {"uid", "ttft", "tpot", "e2e"} <= set(row)
        for drv in ("overlapped", "sync"):
            sla = leg[drv]["sla"]
            for metric in ("ttft", "tpot", "e2e", "queue_wait"):
                assert {"p50", "p90", "p99"} <= set(sla[metric]), metric
    print(csv_row("sla_schema_ok", 0.0, "BENCH_serving.json[sla]"))


# ----------------------------------------------------------- prefix suite --
def _greedy(bundle, prompt, n):
    import jax.numpy as jnp
    from repro.core import pipeline as pl
    out = pl.generate(bundle, jnp.asarray(prompt)[None], max_new=n,
                      collect_stats=False)
    return np.asarray(out["tokens"])[0]


def run_prefix(quick: bool = False) -> None:
    gamma, k = (4, 2) if quick else (5, 2)
    batch = 2
    n_fleet = 3 if quick else 5
    bundle = _tiny_bundle(gamma, k, vocab=VOCAB)
    v = bundle.target_cfg.vocab_size
    rng = np.random.default_rng(0)
    sysp = rng.integers(3, v, size=21).astype(np.int32)
    turn1 = []
    for i in range(n_fleet):
        tail = rng.integers(3, v, size=4 + i).astype(np.int32)
        turn1.append((np.concatenate([sysp, tail]), 4 + (i % 3)))
    turn2 = []
    for p, n in turn1[: max(n_fleet - 1, 1)]:
        ans = _greedy(bundle, p, n)
        turn2.append((np.concatenate(
            [p, ans, rng.integers(3, v, size=5).astype(np.int32)]),
            3 if quick else 5))
    reqs = turn1 + turn2

    off, off_out = _serve(bundle, reqs, batch, cache_impl="paged")
    on, on_out = _serve(bundle, reqs, batch, cache_impl="paged",
                        prefix_cache=True)
    tokens_equal = off_out == on_out
    assert tokens_equal, "prefix cache changed per-request output"
    assert on["prefix_hits"] > 0, "shared-prefix replay produced no hits"
    assert on["prefill_tokens_saved"] > 0
    assert on["cow_copies"] > 0, "no mid-page match exercised COW"
    assert off["prefix_hits"] == 0

    _row("serving_paged_prefix_off", off)
    _row("serving_paged_prefix_on", on)
    total_prompt_tokens = sum(len(p) for p, _ in reqs)
    # hit rate = fraction of submitted prompt tokens served from shared
    # pages; prefill_tokens_saved is bucket-denominated (what the install
    # prefill actually skips vs a cold bucketed install) and can exceed
    # the raw matched count
    hit_rate = on["prefix_hit_tokens"] / total_prompt_tokens
    print(csv_row("serving_prefix_hit_rate", 0.0,
                  f"hit_tokens={on['prefix_hit_tokens']}/"
                  f"{total_prompt_tokens} ({hit_rate:.1%}) "
                  f"saved_prefill_tokens={on['prefill_tokens_saved']} "
                  f"hits={on['prefix_hits']}/"
                  f"{on['prefix_hits'] + on['prefix_misses']} "
                  f"cow={on['cow_copies']} "
                  f"evictions={on['prefix_evictions']} "
                  f"tokens_equal={tokens_equal}"))

    _merge_bench_json("prefix", {
        "config": {"gamma": gamma, "k": k, "batch": batch,
                   "n_requests": len(reqs), "quick": quick,
                   "page_size": PAGE_SIZE, "vocab": VOCAB,
                   "system_prompt_len": len(sysp)},
        "cache_off": dict(off),
        "cache_on": dict(on),
        "tokens_equal": tokens_equal,
        "prompt_tokens_total": total_prompt_tokens,
        "prefill_token_hit_rate": hit_rate,
    })


# ---------------------------------------------------------- resident suite -
def _resident_rounds(bundle, quick: bool):
    """Submit→drain rounds for a resident server: round 1 is a shared-
    system-prompt fleet, each later round's prompts extend the previous
    round's committed prompt+answer strings (multi-turn sessions)."""
    v = bundle.target_cfg.vocab_size
    rng = np.random.default_rng(0)
    sysp = rng.integers(3, v, size=18).astype(np.int32)
    n_fleet = 2 if quick else 4
    n_rounds = 2 if quick else 3
    rounds = [[]]
    for i in range(n_fleet):
        tail = rng.integers(3, v, size=4 + i).astype(np.int32)
        rounds[0].append((np.concatenate([sysp, tail]), 4 + (i % 2)))
    for _ in range(n_rounds - 1):
        prev, nxt = rounds[-1], []
        for p, n in prev:
            ans = _greedy(bundle, p, n)
            nxt.append((np.concatenate(
                [p, ans, rng.integers(3, v, size=4).astype(np.int32)]),
                3 if quick else 4))
        rounds.append(nxt)
    return rounds


def _serve_resident(bundle, rounds, batch: int, **kw):
    """One resident engine across every round; returns (per-round stats
    snapshots, final stats, per-request outputs)."""
    eng = ServingEngine(bundle, batch_size=batch, seed=0,
                        cache_impl="paged", page_size=PAGE_SIZE,
                        pool_headroom=1.5, **kw)
    marks = []
    for reqs in rounds:
        for p, n in reqs:
            eng.submit(p, max_new=n)
        marks.append(eng.run())     # cumulative snapshot incl. tokens_per_s
    outs = {r.uid: r.out.tolist() for r in eng.done}
    return marks, marks[-1], outs


def run_resident(quick: bool = False) -> None:
    gamma, k = (4, 2) if quick else (5, 2)
    batch = 2
    bundle = _tiny_bundle(gamma, k, vocab=VOCAB)
    rounds = _resident_rounds(bundle, quick)

    _, legacy, legacy_out = _serve_resident(bundle, rounds, batch,
                                            pool_scope="wave")
    _, off, off_out = _serve_resident(bundle, rounds, batch)
    marks, on, on_out = _serve_resident(bundle, rounds, batch,
                                        prefix_cache=True)
    tokens_equal = legacy_out == off_out == on_out
    assert tokens_equal, "pool scope / prefix cache changed request output"
    # the resident acceptance criterion: prompts of round N+1 hit prefixes
    # the radix tree committed in round N's wave — hits must be recorded
    # AFTER the first wave turnover
    assert on["waves"] >= len(rounds), (on["waves"], len(rounds))
    cross_wave_hit_tokens = (on["prefix_hit_tokens"]
                             - marks[0]["prefix_hit_tokens"])
    assert cross_wave_hit_tokens > 0, \
        "no prefix cached in wave N was hit in wave N+1"
    assert off["prefix_hits"] == 0 and legacy["prefix_hits"] == 0

    _row("resident_legacy_wave_pools", legacy)
    _row("resident_engine_pool_cache_off", off)
    _row("resident_engine_pool_cache_on", on)
    total_prompt_tokens = sum(len(p) for rs in rounds for p, _ in rs)
    hit_rate = on["prefix_hit_tokens"] / total_prompt_tokens
    print(csv_row(
        "resident_cross_wave_hits", 0.0,
        f"cross_wave_hit_tokens={cross_wave_hit_tokens} "
        f"hit_tokens={on['prefix_hit_tokens']}/{total_prompt_tokens} "
        f"({hit_rate:.1%}) saved_prefill_tokens="
        f"{on['prefill_tokens_saved']} waves={on['waves']} "
        f"cached_pages={on['prefix_cached_pages']}/{on['pool_pages']} "
        f"tokens_equal={tokens_equal}"))

    _merge_bench_json("resident", {
        "config": {"gamma": gamma, "k": k, "batch": batch,
                   "n_rounds": len(rounds),
                   "n_requests": sum(len(r) for r in rounds),
                   "quick": quick, "page_size": PAGE_SIZE, "vocab": VOCAB},
        "legacy_wave_pools": dict(legacy),
        "engine_pool_cache_off": dict(off),
        "engine_pool_cache_on": dict(on),
        "per_round_cache_on": marks,
        "tokens_equal": tokens_equal,
        "cross_wave_hit_tokens": cross_wave_hit_tokens,
        "prompt_tokens_total": total_prompt_tokens,
        "prefill_token_hit_rate": hit_rate,
    })


# ------------------------------------------------------- bytes-model suite -
def _cycle_hlo_stats(bundle, batch: int, max_len: int):
    """gather/dynamic-slice bytes of ONE compiled decode cycle."""
    import jax
    from repro.core import pipeline as pl
    from repro.core.state import engine_init
    from repro.roofline.hlo_analysis import analyze_hlo_text

    state = engine_init(bundle, batch, max_len, cache_impl="paged",
                        page_size=PAGE_SIZE)
    key = jax.random.PRNGKey(0)
    txt = (pl._cycle_jit.lower(bundle, state, key, collect_stats=False,
                               shard_tag=None).compile().as_text())
    t = analyze_hlo_text(txt)
    return {"gather_bytes": t["gather_bytes"],
            "dynamic_slice_bytes": t["dynamic_slice_bytes"]}


def run_bytes_model(quick: bool = False) -> None:
    """Attributable bytes-moved-per-decode-cycle: gather vs pallas.

    Two attributions land in ``BENCH_serving.json[bytes_model]``:

    * **analytic** (``roofline/bytes_model.py``): paged-cache read bytes
      per cycle priced from config + geometry, swept over live cache
      length at fixed capacity and over capacity at fixed live length.
      Asserted shape of the claim: kernel-path bytes grow with LIVE
      length and are capacity-flat; gather-path bytes grow with CAPACITY
      and are live-length-flat.
    * **hlo** (``roofline/hlo_analysis.py``): gather / dynamic-slice
      result bytes of the actual compiled decode cycle for both impls.
      The gather path materializes capacity-sized pool_view gathers; the
      kernel path (interpret mode on CPU) only dynamic-slices page
      blocks — asserted strictly fewer gather bytes.
    """
    from repro.core import pipeline as pl
    from repro.roofline import bytes_model as bm

    gamma, k = (4, 2) if quick else (5, 2)
    batch = 2
    bundle = _tiny_bundle(gamma, k, vocab=VOCAB)
    tcfg, d1, d2 = bundle.target_cfg, bundle.d1_cfg, bundle.d2_cfg

    cap_pages = 8 if quick else 16
    cap = cap_pages * PAGE_SIZE
    live_sweep = sorted({PAGE_SIZE, cap // 4, cap // 2, cap})
    curves = {"gather": [], "pallas": []}
    for impl in ("gather", "pallas"):
        for clen in live_sweep:
            curves[impl].append(bm.cycle_read_bytes(
                tcfg, d1, d2, batch=batch, page_size=PAGE_SIZE,
                max_pages=cap_pages, cache_len=clen, impl=impl))
    cap_sweep = [cap_pages, cap_pages * 2, cap_pages * 4]
    cap_curves = {"gather": [], "pallas": []}
    for impl in ("gather", "pallas"):
        for mp in cap_sweep:
            cap_curves[impl].append(bm.cycle_read_bytes(
                tcfg, d1, d2, batch=batch, page_size=PAGE_SIZE,
                max_pages=mp, cache_len=PAGE_SIZE * 2, impl=impl))

    # the acceptance-criterion shape, asserted
    pal_tot = [c["total"] for c in curves["pallas"]]
    gat_tot = [c["total"] for c in curves["gather"]]
    assert all(a < b for a, b in zip(pal_tot, pal_tot[1:])), (
        "kernel-path bytes must grow with live cache length", pal_tot)
    assert len(set(gat_tot)) == 1, (
        "gather-path bytes must be flat in live length", gat_tot)
    gat_cap = [c["total"] for c in cap_curves["gather"]]
    pal_cap = [c["total"] for c in cap_curves["pallas"]]
    assert all(a < b for a, b in zip(gat_cap, gat_cap[1:])), (
        "gather-path bytes must grow with capacity", gat_cap)
    assert len(set(pal_cap)) == 1, (
        "kernel-path bytes must be flat in capacity", pal_cap)

    # ---- rolling local layers (dense window-capped buffers) ----
    # gather reads the buffer + materializes the [cache; block] concat +
    # re-reads it (3x window cap); the kernel streams the buffer once,
    # padded to the split grid. Both are window-capped — flat in pool
    # capacity — so the claim here is 3x -> ~1x, not live-length scaling.
    import dataclasses as _dc
    win_sweep = [600, 1100, 2100]            # non-bk-aligned (bk=512)
    roll_curves = {"gather": [], "pallas": []}
    for impl in ("gather", "pallas"):
        for w in win_sweep:
            hcfg = _dc.replace(tcfg, layer_pattern=("local", "global"),
                               sliding_window=w)
            roll_curves[impl].append(bm.target_read_bytes(
                hcfg, batch=batch, page_size=PAGE_SIZE,
                max_pages=4 * max(win_sweep) // PAGE_SIZE,
                cache_len=PAGE_SIZE, impl=impl))
    for g, p in zip(roll_curves["gather"], roll_curves["pallas"]):
        assert g["rolling_attend_read"] > 0 and "rolling_kernel_stream" in p
        roll_g = sum(v for k2, v in g.items() if k2.startswith("rolling"))
        assert p["rolling_kernel_stream"] < roll_g, (
            "kernel must stream fewer rolling bytes than 3x gather",
            p["rolling_kernel_stream"], roll_g)
    # window-capped: capacity growth does not move rolling bytes
    hcfg = _dc.replace(tcfg, layer_pattern=("local",), sliding_window=600)
    flat = [bm.target_read_bytes(hcfg, batch=batch, page_size=PAGE_SIZE,
                                 max_pages=mp, cache_len=PAGE_SIZE,
                                 impl=i)["total"]
            for i in ("gather", "pallas") for mp in (64, 256)]
    assert flat[0] == flat[1] and flat[2] == flat[3], (
        "rolling bytes must be window-capped, flat in capacity", flat)

    # ---- sharded drafter feature-cache reads (shard_map hook) ----
    # per-shard bytes divide by kv_shards on both impls; the kernel~live /
    # gather~capacity scaling must survive sharding.
    nsh = 4
    sh_live = {"gather": [], "pallas": []}
    sh_cap = {"gather": [], "pallas": []}
    for impl in ("gather", "pallas"):
        for clen in live_sweep:
            sh_live[impl].append(bm.drafter_read_bytes(
                d1, batch=batch, page_size=PAGE_SIZE, max_pages=cap_pages,
                cache_len=clen, impl=impl, kv_shards=nsh))
        for mp in cap_sweep:
            sh_cap[impl].append(bm.drafter_read_bytes(
                d1, batch=batch, page_size=PAGE_SIZE, max_pages=mp,
                cache_len=PAGE_SIZE * 2, impl=impl, kv_shards=nsh))
    sp = [c["total"] for c in sh_live["pallas"]]
    sg = [c["total"] for c in sh_cap["gather"]]
    assert all(a < b for a, b in zip(sp, sp[1:])), (
        "sharded drafter kernel bytes must grow with live length", sp)
    assert all(a < b for a, b in zip(sg, sg[1:])), (
        "sharded drafter gather bytes must grow with capacity", sg)
    assert len({c["total"] for c in sh_live["gather"]}) == 1
    assert len({c["total"] for c in sh_cap["pallas"]}) == 1
    unsh = bm.drafter_read_bytes(
        d1, batch=batch, page_size=PAGE_SIZE, max_pages=cap_pages,
        cache_len=live_sweep[0], impl="pallas", kv_shards=1)
    assert sh_live["pallas"][0]["total"] * nsh == unsh["total"], (
        "per-shard kernel bytes must be the unsharded figure / kv_shards")

    hlo = {
        "gather": _cycle_hlo_stats(bundle, batch, cap),
        "pallas": _cycle_hlo_stats(pl.with_attn_impl(bundle, "pallas"),
                                   batch, cap),
    }
    assert hlo["pallas"]["gather_bytes"] < hlo["gather"]["gather_bytes"], (
        "kernel path should gather strictly fewer bytes per cycle", hlo)

    for impl in ("gather", "pallas"):
        lo, hi = curves[impl][0]["total"], curves[impl][-1]["total"]
        print(csv_row(
            f"bytes_model_{impl}", 0.0,
            f"live={live_sweep[0]}..{live_sweep[-1]} "
            f"bytes={lo:.0f}..{hi:.0f} "
            f"hlo_gather_bytes={hlo[impl]['gather_bytes']:.0f} "
            f"hlo_dynslice_bytes={hlo[impl]['dynamic_slice_bytes']:.0f}"))
    ratio = gat_tot[0] / pal_tot[0]
    print(csv_row("bytes_model_win_at_min_live", 0.0,
                  f"gather/pallas={ratio:.1f}x at live={live_sweep[0]} "
                  f"cap={cap}"))

    _merge_bench_json("bytes_model", {
        "config": {"gamma": gamma, "k": k, "batch": batch, "quick": quick,
                   "page_size": PAGE_SIZE, "vocab": VOCAB,
                   "capacity_pages": cap_pages, "live_sweep": live_sweep,
                   "capacity_sweep_pages": cap_sweep},
        "analytic_vs_live": curves,
        "analytic_vs_capacity": cap_curves,
        "rolling_vs_window": {"window_sweep": win_sweep, **roll_curves},
        "sharded_drafter": {"kv_shards": nsh,
                            "analytic_vs_live": sh_live,
                            "analytic_vs_capacity": sh_cap},
        "hlo_decode_cycle": hlo,
        "scaling": {
            "pallas_grows_with_live": True,
            "gather_flat_in_live": True,
            "gather_grows_with_capacity": True,
            "pallas_flat_in_capacity": True,
            "gather_over_pallas_at_min_live": ratio,
            "rolling_kernel_under_3x_gather": True,
            "rolling_flat_in_capacity": True,
            "sharded_drafter_pallas_grows_with_live": True,
            "sharded_drafter_gather_grows_with_capacity": True,
            "sharded_drafter_per_shard_division": True,
        },
    })


# ----------------------------------------------------------- sharded suite -
def _run_sharded_inline(quick: bool) -> None:
    import contextlib

    from repro.distributed import sharding as sh
    from repro.launch.mesh import make_mesh

    gamma, k = (4, 2) if quick else (5, 2)
    batch = 2
    bundle = _tiny_bundle(gamma, k, vocab=VOCAB)
    rounds = _resident_rounds(bundle, quick)

    def leg(mesh):
        ctx = (sh.use_sharding(mesh, dict(sh.LOGICAL_RULES, kv_seq="model"))
               if mesh is not None else contextlib.nullcontext())
        with ctx:
            return _serve_resident(bundle, rounds, batch, prefix_cache=True)

    marks_ref, ref, ref_out = leg(None)
    marks_sh, shd, sh_out = leg(make_mesh(data=1, model=4))
    tokens_equal = sh_out == ref_out
    assert tokens_equal, \
        "kv_seq sharding changed per-request output"
    assert shd["kv_shards"] == 4, shd["kv_shards"]
    # resident acceptance across the mesh: wave-N prefixes hit in wave N+1
    cross_wave_hit_tokens = (shd["prefix_hit_tokens"]
                             - marks_sh[0]["prefix_hit_tokens"])
    assert cross_wave_hit_tokens > 0, \
        "no prefix cached in wave N was hit in wave N+1 (sharded engine)"
    assert shd["decode_collective_bytes"] > 0, shd

    _row("sharded_single_device", ref)
    _row("sharded_kv_seq_4way", shd)
    print(csv_row(
        "sharded_pool_placement", 0.0,
        f"kv_shards={shd['kv_shards']} "
        f"shard_slots={shd['pool_shard_slots']} "
        f"pool_util={shd['pool_utilization']:.2f} "
        f"decode_collective_bytes={shd['decode_collective_bytes']} "
        f"cross_wave_hit_tokens={cross_wave_hit_tokens} "
        f"tokens_equal={tokens_equal}"))

    _merge_bench_json("sharded", {
        "config": {"gamma": gamma, "k": k, "batch": batch,
                   "n_rounds": len(rounds),
                   "n_requests": sum(len(r) for r in rounds),
                   "quick": quick, "page_size": PAGE_SIZE, "vocab": VOCAB,
                   "mesh": {"data": 1, "model": 4, "kv_seq_axis": "model"}},
        "single_device": dict(ref),
        "sharded": dict(shd),
        "per_round_sharded": marks_sh,
        "tokens_equal": tokens_equal,
        "cross_wave_hit_tokens": cross_wave_hit_tokens,
        # per-shard pool view: page IDENTITY is global, so occupancy (and
        # hence utilization) is identical on every shard — what differs
        # is the per-shard footprint, pool_shard_slots KV slots per shard
        "pool_shard_slots": shd["pool_shard_slots"],
        "pool_shard_utilization": shd["pool_utilization"],
        "decode_collective_bytes": shd["decode_collective_bytes"],
    })


def run_sharded(quick: bool = False) -> None:
    """Sharded resident serving: the resident submit→drain burst schedule
    replayed through ONE engine spanning a 4-way ``kv_seq`` host mesh vs
    the single-device engine. Asserts per-request token identity, cross-
    wave prefix hits through the sharded engine pool, and reports the
    per-shard pool placement (``pool_shard_slots`` slots/shard, identical
    per-shard utilization — page identity is global) plus the
    ``decode_collective_bytes`` the verify LSE-psum moves per run.
    Re-execs itself under ``XLA_FLAGS=--xla_force_host_platform_device_
    count=4`` when fewer than 4 devices are visible (the usual CPU case).
    """
    import jax
    if jax.device_count() >= 4:
        _run_sharded_inline(quick)
        return
    import os
    import subprocess
    root = Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = str(root / "src")
    env.pop("JAX_PLATFORMS", None)
    cmd = [sys.executable, "-m", "benchmarks.serving_bench", "--sharded"]
    if quick:
        cmd.append("--quick")
    out = subprocess.run(cmd, env=env, cwd=str(root), capture_output=True,
                         text=True, timeout=1800)
    sys.stdout.write(out.stdout)
    if out.returncode != 0:
        sys.stderr.write(out.stderr[-3000:] + "\n")
        raise RuntimeError("sharded serving bench subprocess failed")


if __name__ == "__main__":
    if "--sla" in sys.argv:
        run_sla("--quick" in sys.argv)
    elif "--resident" in sys.argv:
        run_resident("--quick" in sys.argv)
    elif "--prefix" in sys.argv:
        run_prefix("--quick" in sys.argv)
    elif "--sharded" in sys.argv:
        run_sharded("--quick" in sys.argv)
    elif "--bytes-model" in sys.argv:
        run_bytes_model("--quick" in sys.argv)
    else:
        run("--quick" in sys.argv)
