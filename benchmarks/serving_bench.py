"""Serving micro-benchmark: batching policy AND KV storage A/B.

Replays the same mixed traffic (one long budget + sustained short
requests, mixed prompt lengths) through :class:`ServingEngine` three
times —

* ``legacy_waves``      — ``early_exit=False, refill=False``, dense KV
  (the drain-the-wave engine);
* ``early_exit_refill`` — both batching optimizations on, dense KV;
* ``paged``             — batching optimizations on, ``cache_impl="paged"``
  (page-pool KV storage, page-granular admission, copy-free refill);

and reports tokens/s, ``wasted_row_cycles`` (batch rows that spent a
decode cycle without a live, unfinished request), pool utilization, and
``refill_copy_bytes`` — the accounting model of bytes each slot install
writes (dense: a full ``max_len`` row per cache; paged: prompt-sized
tail-page writes + one page-table row). Per-request token output is
asserted identical across ALL configurations (greedy decoding, per-row
isolation, exact logical-view equivalence of the paged layout), so the
deltas are pure batching / memory-subsystem efficiency. Results land in
``BENCH_serving.json`` at the repo root.

Needs no trained study artifacts — builds a tiny random bundle:

    PYTHONPATH=src python -m benchmarks.run --suite serving [--quick]
"""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from benchmarks.common import csv_row
from benchmarks.engine_bench import _tiny_bundle
from repro.serving.engine import ServingEngine

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_serving.json"
PAGE_SIZE = 16


def _traffic(vocab: int, quick: bool):
    """One long request up front + sustained short/mid traffic."""
    rng = np.random.default_rng(0)
    if quick:
        budgets = [20, 4, 6, 4, 5, 4]
        plens = [10, 8, 9, 8, 11, 8]
    else:
        budgets = [32, 6, 8, 5, 10, 6, 7, 5, 9, 6, 8, 5]
        plens = [14, 8, 10, 9, 12, 8, 11, 9, 10, 8, 9, 12]
    return [(rng.integers(3, vocab, size=p).astype(np.int32), n)
            for p, n in zip(plens, budgets)]


def _serve(bundle, reqs, batch: int, early_exit: bool, refill: bool,
           cache_impl: str = "dense"):
    eng = ServingEngine(bundle, batch_size=batch, seed=0,
                        early_exit=early_exit, refill=refill,
                        cache_impl=cache_impl, page_size=PAGE_SIZE)
    for p, n in reqs:
        eng.submit(p, max_new=n)
    t0 = time.time()
    stats = eng.run()
    stats["wall_clock_s"] = time.time() - t0
    outs = {r.uid: r.out.tolist() for r in eng.done}
    return stats, outs


def run(quick: bool = False) -> None:
    gamma, k = (4, 2) if quick else (6, 2)
    batch = 2 if quick else 3
    bundle = _tiny_bundle(gamma, k)
    reqs = _traffic(bundle.target_cfg.vocab_size, quick)

    base, base_out = _serve(bundle, reqs, batch, early_exit=False,
                            refill=False)
    opt, opt_out = _serve(bundle, reqs, batch, early_exit=True, refill=True)
    pgd, pgd_out = _serve(bundle, reqs, batch, early_exit=True, refill=True,
                          cache_impl="paged")
    tokens_equal = base_out == opt_out == pgd_out
    assert tokens_equal, "batching/storage config changed per-request output"
    # copy-free refill acceptance: paged installs write page-order bytes
    assert pgd["installs"] == opt["installs"]
    assert pgd["refill_copy_bytes"] * 2 < opt["refill_copy_bytes"], (
        pgd["refill_copy_bytes"], opt["refill_copy_bytes"])

    def row(name, s):
        extra = ""
        if s.get("pool_pages"):
            extra = (f" pool_util={s['pool_utilization']:.2f} "
                     f"pool_peak={s['pool_peak_pages']}/{s['pool_pages']}")
        print(csv_row(
            name, s["wall_clock_s"] * 1e6,
            f"tokens_per_s={s['tokens_per_s']:.1f} "
            f"wasted_row_cycles={s['wasted_row_cycles']} "
            f"alpha={s['alpha']:.3f} waves={s['waves']} "
            f"refills={s['refills']} "
            f"refill_copy_bytes={s['refill_copy_bytes']}" + extra))

    row("serving_legacy_waves", base)
    row("serving_early_exit_refill", opt)
    row("serving_paged_kv", pgd)
    saved = base["wasted_row_cycles"] - opt["wasted_row_cycles"]
    copy_ratio = (opt["refill_copy_bytes"] / pgd["refill_copy_bytes"]
                  if pgd["refill_copy_bytes"] else float("inf"))
    print(csv_row("serving_wasted_cycle_reduction", 0.0,
                  f"saved={saved} tokens_equal={tokens_equal}"))
    print(csv_row("serving_refill_copy_reduction", 0.0,
                  f"dense/paged={copy_ratio:.1f}x"))

    payload = {
        "config": {"gamma": gamma, "k": k, "batch": batch,
                   "n_requests": len(reqs), "quick": quick,
                   "page_size": PAGE_SIZE},
        "legacy_waves": {k2: v for k2, v in base.items()},
        "early_exit_refill": {k2: v for k2, v in opt.items()},
        "paged": {k2: v for k2, v in pgd.items()},
        "tokens_equal": tokens_equal,
        "wasted_row_cycles_saved": saved,
        "refill_copy_bytes_dense_over_paged": copy_ratio,
    }
    BENCH_PATH.write_text(json.dumps(payload, indent=2, default=float))
    print(f"wrote {BENCH_PATH}")


if __name__ == "__main__":
    run("--quick" in sys.argv)
