"""Paper Table 7: stacking a third VP-Drafter level — alpha rises, modeled
speedup falls (the cascade-depth asymmetry)."""
from __future__ import annotations

from benchmarks.common import measure

METHODS = ["d2sd", "d2sd_l3"]


def run(quick: bool = False):
    tasks = ["math", "code"] if not quick else ["math"]
    print("# Table 7 — D2SD vs +3rd draft level (speedup x / alpha)")
    print("task," + ",".join(f"{m}_speedup,{m}_alpha" for m in METHODS))
    out = {}
    for task in tasks:
        cells = []
        for m in METHODS:
            r = measure(m, task, n_prompts=4 if quick else 8,
                        max_new=48 if quick else 80)
            cells.append((r.speedup, r.alpha))
            out[(task, m)] = r
        print(f"{task}," + ",".join(f"{s:.2f},{a:.2f}" for s, a in cells))
    return out


if __name__ == "__main__":
    run()
