"""Engine micro-benchmark: host-loop ``generate`` vs on-device
``generate_ondevice`` tokens/s.

Needs no trained study artifacts — builds a tiny random bundle, so it can
run in any environment (it measures loop/dispatch overhead, not model
quality). The on-device path removes the per-cycle host sync + numpy
copy-out; on small CPU models that overhead dominates, which is exactly
what this section quantifies.

    PYTHONPATH=src python -m benchmarks.run --only engine [--quick]
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from benchmarks.common import csv_row
from repro.config.base import ModelConfig, SpecConfig
from repro.core import pipeline as pl
from repro.core.drafter import DrafterConfig, drafter_init
from repro.models import lm


def _tiny_bundle(gamma: int, k: int, vocab: int = 199) -> pl.SpecBundle:
    tcfg = ModelConfig(num_layers=4, d_model=128, num_heads=4,
                       num_kv_heads=2, d_ff=256, vocab_size=vocab,
                       max_seq_len=1024, remat=False, dtype="float32")
    dcfg = DrafterConfig(d_model=64, num_layers=2, num_heads=2,
                         num_kv_heads=2, d_ff=128, vocab_size=vocab,
                         target_feature_dim=lm.feature_dim(tcfg),
                         gamma=gamma, dtype="float32")
    tp = lm.lm_init(jax.random.PRNGKey(0), tcfg)
    d1 = drafter_init(jax.random.PRNGKey(1), dcfg)
    d2 = drafter_init(jax.random.PRNGKey(2), dcfg)
    spec = SpecConfig(gamma=gamma, top_k_branches=k, mode="d2sd")
    return pl.SpecBundle(tcfg, dcfg, dcfg, spec, tp, d1, d2)


def _time(fn, repeats: int) -> float:
    fn()                                     # warmup / compile
    t0 = time.time()
    for _ in range(repeats):
        fn()
    return (time.time() - t0) / repeats


def run(quick: bool = False) -> None:
    gamma, k = (6, 2) if quick else (8, 3)
    batch, max_new = (2, 24) if quick else (4, 48)
    repeats = 2 if quick else 3
    bundle = _tiny_bundle(gamma, k)
    prompts = jax.random.randint(jax.random.PRNGKey(3), (batch, 12), 3,
                                 bundle.target_cfg.vocab_size)
    key = jax.random.PRNGKey(7)

    host_s = _time(lambda: pl.generate(bundle, prompts, max_new=max_new,
                                       key=key, collect_stats=False),
                   repeats)
    dev_s = _time(lambda: np.asarray(
        pl.generate_ondevice(bundle, prompts, max_new=max_new,
                             key=key)["tokens"]), repeats)
    n_tok = batch * max_new
    print(csv_row("generate_host_loop", host_s * 1e6,
                  f"tokens_per_s={n_tok / host_s:.1f}"))
    print(csv_row("generate_ondevice", dev_s * 1e6,
                  f"tokens_per_s={n_tok / dev_s:.1f}"))
    print(csv_row("ondevice_speedup", 0.0,
                  f"x{host_s / dev_s:.2f} host/ondevice wall ratio"))


if __name__ == "__main__":
    run("--quick" in sys.argv)
