"""Paper Table 6: reuse DFlash as the second drafter (no variable-prefix
training) inside the full cascade pipeline — isolates the VP recipe's
contribution (Eq. 6/7)."""
from __future__ import annotations

from benchmarks.common import measure

METHODS = ["dflash", "dflash_second", "d2sd"]


def run(quick: bool = False):
    tasks = ["math", "code", "chat"] if not quick else ["math"]
    print("# Table 6 — DFlash -> DFlash vs D2SD (speedup x / alpha)")
    print("task," + ",".join(f"{m}_speedup,{m}_alpha" for m in METHODS))
    out = {}
    for task in tasks:
        cells = []
        for m in METHODS:
            r = measure(m, task, n_prompts=4 if quick else 10,
                        max_new=48 if quick else 96)
            cells.append((r.speedup, r.alpha))
            out[(task, m)] = r
        print(f"{task}," + ",".join(f"{s:.2f},{a:.2f}" for s, a in cells))
    return out


if __name__ == "__main__":
    run()
