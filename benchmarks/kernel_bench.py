"""Kernel microbench: wall time of the pure-jnp reference paths on CPU (the
Pallas kernels target TPU and are validated in interpret mode — their CPU
interpret time is not meaningful), plus analytic kernel FLOPs for roofline
cross-checks."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import csv_row
from repro.kernels import ref


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters * 1e6


def run(quick: bool = False):
    print("# kernel reference microbench  name,us_per_call,derived")
    cases = [
        ("flash_ref_prefill", (2, 8, 2, 512, 512, 64)),
        ("flash_ref_decode", (8, 8, 2, 16, 2048, 64)),
    ]
    if quick:
        cases = cases[:1]
    for name, (b, hq, hkv, tq, tkv, d) in cases:
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (b, hq, tq, d), jnp.float32)
        k = jax.random.normal(ks[1], (b, hkv, tkv, d), jnp.float32)
        v = jax.random.normal(ks[2], (b, hkv, tkv, d), jnp.float32)
        f = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v)[0])
        us = _time(f, q, k, v)
        flops = 4 * b * hq * tq * tkv * d
        print(csv_row(name, us, f"flops={flops:.3g}"))
    return True


if __name__ == "__main__":
    run()
