"""Kernel microbench.

Two sections, both emitted into ``BENCH_kernels.json`` (section
``kernels``) via ``benchmarks/run.py --suite kernels``:

* ``ref`` — wall time of the pure-jnp reference flash path on CPU (the
  Pallas kernels target TPU and are validated in interpret mode — their
  CPU interpret time is not meaningful), plus analytic kernel FLOPs for
  roofline cross-checks.
* ``paged_cascade_ab`` — gather vs kernel READ-PATH A/B on one cascade
  verify call over a paged cache: the gather leg materializes the dense
  logical view from the page pool (exactly what ``kvcache.pool_view``
  does) and runs the dense cascade; the pallas leg calls
  ``ops.cascade_attention_paged`` directly on the pool + page table
  (interpret mode on CPU). Outputs are asserted numerically equal and
  each case reports the analytic read bytes of both paths
  (``roofline/bytes_model.py`` counting rules: gather moves 3x
  capacity-sized traffic, the kernel streams ceil(live/page) pages), so
  the A/B is attributable, not just timed.
* ``rolling_cascade_ab`` — the same A/B on ROLLING sliding-window
  buffers at non-block-aligned capacities (the configurations the old
  ``cap=s_pad`` plumbing recovered wrong positions for): gather
  materializes the [cache; block] concat (3x window-capped capacity),
  the kernel streams the buffer once, padded to the split grid. Outputs
  asserted equal against ``attend_cache_plus_block`` with rolling
  position recovery.
"""
from __future__ import annotations

import math
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, merge_bench_json
from repro.kernels import ops as kops
from repro.kernels import ref

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_kernels.json"


def _time(fn, *args, iters=5):
    jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters * 1e6


def _ref_section(quick: bool):
    cases = [
        ("flash_ref_prefill", (2, 8, 2, 512, 512, 64)),
        ("flash_ref_decode", (8, 8, 2, 16, 2048, 64)),
    ]
    if quick:
        cases = cases[:1]
    rows = []
    for name, (b, hq, hkv, tq, tkv, d) in cases:
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (b, hq, tq, d), jnp.float32)
        k = jax.random.normal(ks[1], (b, hkv, tkv, d), jnp.float32)
        v = jax.random.normal(ks[2], (b, hkv, tkv, d), jnp.float32)
        f = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v)[0])
        us = _time(f, q, k, v)
        flops = 4 * b * hq * tq * tkv * d
        print(csv_row(name, us, f"flops={flops:.3g}"))
        rows.append({"name": name, "us_per_call": us, "flops": flops})
    return rows


def _paged_case(b, hq, hkv, d, page, max_pages, cache_len, tq, iters):
    """One gather-vs-kernel cascade verify A/B over a paged cache."""
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    n_phys = b * max_pages
    pool_k = jax.random.normal(ks[0], (n_phys, page, hkv, d), jnp.float32)
    pool_v = jax.random.normal(ks[1], (n_phys, page, hkv, d), jnp.float32)
    # per-row page table: row b owns pages [b*mp, (b+1)*mp); pages past
    # the live length carry the out-of-range sentinel, like the engine's
    live_pages = math.ceil(cache_len / page)
    pt = np.full((b, max_pages), n_phys, np.int32)
    for r in range(b):
        pt[r, :live_pages] = r * max_pages + np.arange(live_pages)
    pt = jnp.asarray(pt)
    q = jax.random.normal(ks[2], (b, tq, hq, d), jnp.float32)
    blk_k = jax.random.normal(ks[3], (b, tq, hkv, d), jnp.float32)
    blk_v = jax.random.normal(ks[4], (b, tq, hkv, d), jnp.float32)
    clen = jnp.full((b,), cache_len, jnp.int32)
    q_abs = cache_len + jnp.broadcast_to(jnp.arange(tq, dtype=jnp.int32),
                                         (b, tq))
    tree = jnp.tril(jnp.ones((tq, tq), bool))

    def gather_leg(pool_k, pool_v, pt, q, blk_k, blk_v, clen, q_abs):
        # the pool_view read path: gather every table slot (capacity-
        # sized, dead pages clamped to a live one) into a dense view
        safe = jnp.minimum(pt, n_phys - 1)
        dk = pool_k[safe].reshape(b, max_pages * page, hkv, d)
        dv = pool_v[safe].reshape(b, max_pages * page, hkv, d)
        return kops.cascade_attention(
            q, dk, dv, blk_k, blk_v, cache_len=clen, q_abs=q_abs,
            tree_mask=tree, rolling=False, layout="BTHD")

    def pallas_leg(pool_k, pool_v, pt, q, blk_k, blk_v, clen, q_abs):
        return kops.cascade_attention_paged(
            q, pool_k, pool_v, pt, blk_k, blk_v, cache_len=clen,
            q_abs=q_abs, tree_mask=tree, layout="BTHD")

    args = (pool_k, pool_v, pt, q, blk_k, blk_v, clen, q_abs)
    yg = jax.jit(gather_leg)(*args)
    yp = jax.jit(pallas_leg)(*args)
    err = float(jnp.max(jnp.abs(yg - yp)))
    assert err < 1e-4, f"gather vs pallas mismatch: max err {err}"
    us_g = _time(jax.jit(gather_leg), *args, iters=iters)
    us_p = _time(jax.jit(pallas_leg), *args, iters=iters)
    # analytic read bytes (bytes_model counting rules, 1 layer, K+V)
    slot = hkv * d * 4
    gather_bytes = 3 * b * max_pages * page * slot * 2
    pallas_bytes = b * live_pages * page * slot * 2
    return {
        "batch": b, "page_size": page, "max_pages": max_pages,
        "cache_len": cache_len, "tq": tq,
        "gather_us": us_g, "pallas_interpret_us": us_p,
        "max_abs_err": err,
        "gather_read_bytes": gather_bytes,
        "pallas_read_bytes": pallas_bytes,
    }


def _rolling_case(b, hq, hkv, d, cap, window, cache_len, tq, iters,
                  n_splits=4, bk=64):
    """Gather-vs-kernel A/B on one ROLLING sliding-window cascade call:
    the gather leg concatenates [rolling cache; block] and attends with
    recovered positions (``attend_cache_plus_block`` semantics via the
    oracle); the kernel leg runs the dense cascade with rolling=True and
    the TRUE capacity as modulus."""
    from repro.models.attention import attend_cache_plus_block
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    q = jax.random.normal(ks[0], (b, tq, hq, d), jnp.float32)
    ck = jax.random.normal(ks[1], (b, cap, hkv, d), jnp.float32)
    cv = jax.random.normal(ks[2], (b, cap, hkv, d), jnp.float32)
    blk_k = jax.random.normal(ks[3], (b, tq, hkv, d), jnp.float32)
    blk_v = jax.random.normal(ks[4], (b, tq, hkv, d), jnp.float32)
    clen = jnp.full((b,), cache_len, jnp.int32)
    q_abs = cache_len + jnp.broadcast_to(jnp.arange(tq, dtype=jnp.int32),
                                         (b, tq))
    tree = jnp.tril(jnp.ones((tq, tq), bool))

    def gather_leg(q, ck, cv, blk_k, blk_v, clen, q_abs):
        kk = jnp.concatenate([ck, blk_k], axis=1)
        vv = jnp.concatenate([cv, blk_v], axis=1)
        return attend_cache_plus_block(
            q, kk, vv, cache_cap=cap, cache_len=clen, q_abs=q_abs,
            window=window, extra_mask=tree, attn_softcap=None,
            impl="dense", kv_chunk=1024, rolling=True)

    def kernel_leg(q, ck, cv, blk_k, blk_v, clen, q_abs):
        return kops.cascade_attention(
            q, ck, cv, blk_k, blk_v, cache_len=clen, q_abs=q_abs,
            tree_mask=tree, window=window, rolling=True,
            n_splits=n_splits, bk=bk, interpret=True, layout="BTHD")

    args = (q, ck, cv, blk_k, blk_v, clen, q_abs)
    yg = jax.jit(gather_leg)(*args)
    yp = jax.jit(kernel_leg)(*args)
    err = float(jnp.max(jnp.abs(yg - yp)))
    assert err < 1e-4, f"rolling gather vs kernel mismatch: max err {err}"
    us_g = _time(jax.jit(gather_leg), *args, iters=iters)
    us_p = _time(jax.jit(kernel_leg), *args, iters=iters)
    # analytic read bytes (bytes_model rolling rules, 1 layer, K+V):
    # gather = 3x window-capped capacity, kernel = split-grid-padded cap
    from repro.roofline.bytes_model import rolling_padded_cap
    slot = hkv * d * 4
    pad = rolling_padded_cap(cap, n_splits=n_splits, bk=bk)
    return {
        "batch": b, "capacity": cap, "window": window,
        "cache_len": cache_len, "tq": tq,
        "gather_us": us_g, "pallas_interpret_us": us_p,
        "max_abs_err": err,
        "gather_read_bytes": 3 * b * cap * slot * 2,
        "pallas_read_bytes": b * pad * slot * 2,
    }


def _rolling_section(quick: bool):
    # non-block-aligned capacities (bk=64), pre-wrap and wrapped lens —
    # the configurations the old cap=s_pad plumbing got WRONG
    geoms = [(97, 97, 150), (505, 200, 711)] if quick else [
        (97, 97, 150), (131, 96, 70), (505, 200, 711), (509, 509, 1000)]
    rows = []
    for cap, window, clen in geoms:
        r = _rolling_case(b=2, hq=4, hkv=2, d=16, cap=cap, window=window,
                          cache_len=clen, tq=4, iters=2 if quick else 3)
        print(csv_row(
            f"rolling_cascade_cap{cap}_win{window}_live{clen}",
            r["gather_us"],
            f"pallas_interpret_us={r['pallas_interpret_us']:.1f} "
            f"gather_bytes={r['gather_read_bytes']:.3g} "
            f"pallas_bytes={r['pallas_read_bytes']:.3g} "
            f"max_err={r['max_abs_err']:.2e}"))
        rows.append(r)
    # 3x capacity vs ~1x padded capacity, asserted on the analytic model
    for r in rows:
        assert r["pallas_read_bytes"] < r["gather_read_bytes"], r
    return rows


def _paged_section(quick: bool):
    # fixed live length, growing capacity: gather traffic scales with
    # capacity, the kernel's stays put (the attributable claim)
    geoms = [(4, 24), (16, 24)] if quick else [(4, 24), (16, 24), (32, 24),
                                               (32, 200)]
    rows = []
    for mp, clen in geoms:
        r = _paged_case(b=2, hq=4, hkv=2, d=16, page=16, max_pages=mp,
                        cache_len=clen, tq=4, iters=2 if quick else 3)
        print(csv_row(
            f"paged_cascade_cap{mp}_live{clen}", r["gather_us"],
            f"pallas_interpret_us={r['pallas_interpret_us']:.1f} "
            f"gather_bytes={r['gather_read_bytes']:.3g} "
            f"pallas_bytes={r['pallas_read_bytes']:.3g} "
            f"max_err={r['max_abs_err']:.2e}"))
        rows.append(r)
    # the claim itself, asserted on the analytic model
    by_cap = [r for r in rows if r["cache_len"] == 24]
    assert by_cap[-1]["gather_read_bytes"] > by_cap[0]["gather_read_bytes"]
    assert (by_cap[-1]["pallas_read_bytes"]
            == by_cap[0]["pallas_read_bytes"])
    return rows


def run(quick: bool = False):
    print("# kernel microbench  name,us_per_call,derived")
    ref_rows = _ref_section(quick)
    ab_rows = _paged_section(quick)
    roll_rows = _rolling_section(quick)
    merge_bench_json(BENCH_PATH, "kernels", {
        "ref": ref_rows,
        "paged_cascade_ab": ab_rows,
        "rolling_cascade_ab": roll_rows,
        "notes": "pallas legs run in interpret mode on CPU: correctness "
                 "and bytes attribution are meaningful, wall time is not",
    })
    return True


if __name__ == "__main__":
    import sys
    run("--quick" in sys.argv)
