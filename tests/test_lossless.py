"""THE paper-level invariants:

1. Greedy D2SD (every mode) emits exactly the pure-greedy target rollout,
   even with useless random drafters (longest-correct-prefix rule).
2. Sampled D2SD emits tokens distributed exactly as the target's softmax
   (multi-branch rejection sampling is lossless).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import ModelConfig, SpecConfig
from repro.core import pipeline as pl
from repro.core.drafter import DrafterConfig, drafter_init
from repro.models import lm

from conftest import tiny_target, tiny_drafter, pure_greedy

GAMMA = 6


def _setup(tcfg, gamma=GAMMA, causal=False):
    dcfg = tiny_drafter(vocab=tcfg.vocab_size, target_d=tcfg.d_model,
                        gamma=gamma, dtype=tcfg.dtype, causal=causal,
                        target_cfg=tcfg)
    tp = lm.lm_init(jax.random.PRNGKey(0), tcfg)
    d1 = drafter_init(jax.random.PRNGKey(1), dcfg)
    d2 = drafter_init(jax.random.PRNGKey(2), dcfg)
    prompts = jax.random.randint(jax.random.PRNGKey(3), (3, 8), 0,
                                 tcfg.vocab_size)
    return dcfg, tp, d1, d2, prompts


@pytest.mark.parametrize("mode,third", [
    ("d2sd", False), ("dflash", False), ("naive_k", False),
    ("eagle", False), ("d2sd", True), ("dflash_second", False)])
def test_greedy_exact_attention_target(mode, third):
    # fp32: the equality is exact in exact arithmetic; in bf16 the reference
    # single-token decode path rounds differently from the batched verify
    # pass, so random-weight near-ties can flip argmax (engine-internal
    # consistency still holds via KV gather-commit).
    tcfg = tiny_target(dtype="float32")
    dcfg, tp, d1, d2, prompts = _setup(tcfg, causal=(mode == "eagle"))
    ref = np.asarray(pure_greedy(tp, tcfg, prompts, 16))
    spec = SpecConfig(gamma=GAMMA, top_k_branches=2, mode=mode,
                      temperature=0.0, third_level=third)
    bundle = pl.SpecBundle(tcfg, dcfg, dcfg, spec, tp, d1,
                           d1 if mode == "dflash_second" else d2)
    out = pl.generate(bundle, prompts, max_new=16, key=jax.random.PRNGKey(7))
    assert np.array_equal(out["tokens"], ref), mode


@pytest.mark.parametrize("pat,extra,nl", [
    (("rwkv",), dict(rwkv_head_dim=16), 4),
    (("recurrent", "recurrent", "local"), dict(sliding_window=8), 5),
])
def test_greedy_exact_ssm_target(pat, extra, nl):
    # fp32: the SSM replay-commit recomputes states, exact only up to float
    # associativity in bf16 (DESIGN §5.1); fp32 removes the ambiguity.
    tcfg = tiny_target(dtype="float32", layer_pattern=pat, num_layers=nl,
                       **extra)
    dcfg, tp, d1, d2, prompts = _setup(tcfg)
    assert not pl.uses_tree_attention(tcfg)
    ref = np.asarray(pure_greedy(tp, tcfg, prompts, 14))
    spec = SpecConfig(gamma=GAMMA, top_k_branches=2, mode="d2sd",
                      temperature=0.0)
    bundle = pl.SpecBundle(tcfg, dcfg, dcfg, spec, tp, d1, d2)
    out = pl.generate(bundle, prompts, max_new=14, key=jax.random.PRNGKey(7))
    assert np.array_equal(out["tokens"], ref)


def test_rolling_cache_wraps_correctly():
    """Local-attn target with window << generated length."""
    tcfg = tiny_target(dtype="float32",
                       layer_pattern=("local", "global"), sliding_window=8)
    dcfg, tp, d1, d2, prompts = _setup(tcfg)
    ref = np.asarray(pure_greedy(tp, tcfg, prompts, 24))
    spec = SpecConfig(gamma=GAMMA, top_k_branches=2, mode="d2sd",
                      temperature=0.0)
    bundle = pl.SpecBundle(tcfg, dcfg, dcfg, spec, tp, d1, d2)
    out = pl.generate(bundle, prompts, max_new=24, key=jax.random.PRNGKey(7))
    assert np.array_equal(out["tokens"], ref)


@pytest.mark.slow
def test_sampling_is_lossless_distribution():
    V = 13
    tcfg = ModelConfig(num_layers=2, d_model=32, num_heads=2, num_kv_heads=2,
                       d_ff=64, vocab_size=V, max_seq_len=64, remat=False,
                       dtype="float32")
    dcfg = DrafterConfig(d_model=16, num_layers=1, num_heads=2,
                         num_kv_heads=2, d_ff=32, vocab_size=V,
                         target_feature_dim=2 * 32, gamma=4, dtype="float32")
    tp = lm.lm_init(jax.random.PRNGKey(0), tcfg)
    d1 = drafter_init(jax.random.PRNGKey(1), dcfg)
    d2 = drafter_init(jax.random.PRNGKey(2), dcfg)
    prompts = jax.random.randint(jax.random.PRNGKey(3), (1, 6), 0, V)
    spec = SpecConfig(gamma=4, top_k_branches=2, mode="d2sd", temperature=1.0)
    bundle = pl.SpecBundle(tcfg, dcfg, dcfg, spec, tp, d1, d2)
    state = pl.engine_init(bundle, 1, 32)
    state = pl.prefill(bundle, state, prompts)
    full = jnp.concatenate([prompts, state.anchor[:, None]], 1)
    logits = lm.forward(tp, full, tcfg,
                        remat=False)["logits"][:, -1].astype(jnp.float32)
    p_ref = np.asarray(jax.nn.softmax(logits, -1)[0])

    cyc = jax.jit(lambda e, k: pl.decode_cycle(bundle, e, k, False))
    n = 1500
    counts = np.zeros(V)
    for i in range(n):
        _, out = cyc(state, jax.random.PRNGKey(1000 + i))
        counts[int(np.asarray(out["tokens"][0, 0]))] += 1
    tv = 0.5 * np.abs(counts / n - p_ref).sum()
    noise = float(np.sqrt(V / (4 * n)))
    assert tv < max(0.06, 2.5 * noise), (tv, noise)
