"""Substrate tests: optimizers, checkpoint/restart (incl. elastic restore +
failure injection + exact resume), data determinism, straggler monitor."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.config.base import ModelConfig, OptimizerConfig, TrainConfig
from repro.data.synthetic import SyntheticDataset, TASKS, decode_ids
from repro.models import lm
from repro.optim import optimizers as opt_lib
from repro.training.trainer import InjectedFailure, train


def _tiny():
    return ModelConfig(num_layers=2, d_model=32, num_heads=2, num_kv_heads=2,
                       d_ff=64, vocab_size=512, max_seq_len=64, remat=False)


# ---------------------------------------------------------------- optim ----
@pytest.mark.parametrize("name", ["adamw", "adamw8bit", "adafactor"])
def test_optimizers_reduce_loss(name):
    cfg = _tiny()
    hp = OptimizerConfig(name=name, lr=5e-3, total_steps=30, warmup_steps=2)
    params = lm.lm_init(jax.random.PRNGKey(0), cfg)
    opt_init, opt_update = opt_lib.make_optimizer(hp)
    opt_state = opt_init(params)
    ds = SyntheticDataset("math", 8, 32, seed=0)

    @jax.jit
    def step(params, opt_state, batch):
        loss, g = jax.value_and_grad(
            lambda p: lm.loss_fn(p, batch, cfg))(params)
        p2, o2, _ = opt_update(g, opt_state, params)
        return p2, o2, loss

    losses = []
    for _ in range(30):
        b = ds.next_batch()
        params, opt_state, loss = step(params, opt_state, b)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.8, losses[::10]


def test_int8_moments_match_fp32_closely():
    cfg = _tiny()
    hp = OptimizerConfig(name="adamw", lr=1e-3, total_steps=10)
    params = lm.lm_init(jax.random.PRNGKey(0), cfg)
    g = jax.tree.map(lambda p: jnp.ones_like(p) * 0.01, params)
    s_f = opt_lib.adamw_init(params, quantized=False)
    s_q = opt_lib.adamw_init(params, quantized=True)
    p_f, s_f, _ = opt_lib.adamw_update(g, s_f, params, hp, quantized=False)
    p_q, s_q, _ = opt_lib.adamw_update(g, s_q, params, hp, quantized=True)
    d = max(float(jnp.abs(a - b).max())
            for a, b in zip(jax.tree.leaves(p_f), jax.tree.leaves(p_q)))
    assert d < 1e-4, d


# ----------------------------------------------------------------- data ----
def test_data_deterministic_and_resumable():
    a = SyntheticDataset("math", 4, 32, seed=3)
    b = SyntheticDataset("math", 4, 32, seed=3)
    for _ in range(3):
        a.next_batch()
    state = a.state_dict()
    ba = a.next_batch()
    for _ in range(3):
        b.next_batch()
    b2 = SyntheticDataset("math", 4, 32, seed=999)
    b2.load_state_dict(state)
    bb = b2.next_batch()
    np.testing.assert_array_equal(ba["tokens"], bb["tokens"])


def test_data_sharding_disjoint():
    s0 = SyntheticDataset("code", 4, 32, seed=1, shard_id=0, num_shards=2)
    s1 = SyntheticDataset("code", 4, 32, seed=1, shard_id=1, num_shards=2)
    t0 = s0.next_batch()["tokens"]
    t1 = s1.next_batch()["tokens"]
    assert not np.array_equal(t0, t1)


def test_tasks_look_right():
    ds = SyntheticDataset("math", 1, 48, seed=0)
    s = decode_ids(ds.next_batch()["tokens"][0][1:])
    assert "=" in s and "+" in s, s


# ----------------------------------------------------- checkpoint/fault ----
def test_checkpoint_roundtrip_and_integrity(tmp_path):
    cfg = _tiny()
    params = lm.lm_init(jax.random.PRNGKey(0), cfg)
    ck = Checkpointer(str(tmp_path))
    ck.save(5, {"params": params}, extra={"step": 5})
    restored, extra = ck.restore({"params": params})
    assert extra["step"] == 5
    for a, b in zip(jax.tree.leaves(params),
                    jax.tree.leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # corrupt -> detected
    shard = next((tmp_path / "step_00000005").glob("*.npz"))
    raw = bytearray(shard.read_bytes())
    raw[100] ^= 0xFF
    shard.write_bytes(bytes(raw))
    with pytest.raises(AssertionError, match="corrupted"):
        ck.restore({"params": params})


def test_failure_injection_and_exact_resume(tmp_path):
    """A run with an injected mid-training failure must produce EXACTLY the
    same final params as an uninterrupted run (checkpoint + data-state
    resume)."""
    cfg = _tiny()
    hp = OptimizerConfig(lr=1e-3, total_steps=12, warmup_steps=2)

    def make_step():
        opt_init, opt_update = opt_lib.make_optimizer(hp)

        @jax.jit
        def step(params, opt_state, batch):
            loss, g = jax.value_and_grad(
                lambda p: lm.loss_fn(p, batch, cfg))(params)
            p2, o2, m = opt_update(g, opt_state, params)
            return p2, o2, {"loss": loss, **m}

        return step, opt_init

    def run(inject: bool, ckdir):
        tc = TrainConfig(batch_size=4, seq_len=32,
                         optimizer=hp, checkpoint_every=4,
                         checkpoint_dir=ckdir, log_every=1000)
        step, opt_init = make_step()
        params = lm.lm_init(jax.random.PRNGKey(0), cfg)
        ds = SyntheticDataset("math", 4, 32, seed=0)
        state = {"params": params, "opt_state": opt_init(params), "step": 0}
        fired = {"done": False}

        def pre(step_i):
            if inject and step_i == 6 and not fired["done"]:
                fired["done"] = True
                raise InjectedFailure("simulated node loss")

        out = train(step, state, ds, tc, hooks={"pre_step": pre},
                    log=lambda *a: None)
        return out

    o1 = run(False, str(tmp_path / "a"))
    o2 = run(True, str(tmp_path / "b"))
    assert o2["restarts"] == 1
    for a, b in zip(jax.tree.leaves(o1["state"]["params"]),
                    jax.tree.leaves(o2["state"]["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_straggler_monitor_flags_outliers():
    from repro.training.trainer import StragglerMonitor
    m = StragglerMonitor(threshold=3.0)
    for i in range(20):
        m.record(i, 0.1)
    assert m.record(20, 0.9)
    assert m.flagged == [20]
