"""ServingEngine: early-exit masking, continuous slot refill, FIFO waves,
and the alpha / tokens accounting fixes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import SpecConfig
from repro.core import pipeline as pl
from repro.core.drafter import drafter_init
from repro.core.state import prefill_row
from repro.models import lm
from repro.serving.engine import ServingEngine

from conftest import tiny_target, tiny_drafter, pure_greedy

GAMMA = 6


@pytest.fixture(scope="module")
def bundle():
    tcfg = tiny_target(vocab=61, dtype="float32")
    dcfg = tiny_drafter(vocab=61, gamma=GAMMA, dtype="float32",
                        target_cfg=tcfg)
    tp = lm.lm_init(jax.random.PRNGKey(0), tcfg)
    d1 = drafter_init(jax.random.PRNGKey(1), dcfg)
    d2 = drafter_init(jax.random.PRNGKey(2), dcfg)
    spec = SpecConfig(gamma=GAMMA, top_k_branches=2, mode="d2sd")
    return pl.SpecBundle(tcfg, dcfg, dcfg, spec, tp, d1, d2)


def _ref(bundle, prompt, n):
    return np.asarray(pure_greedy(bundle.target_params, bundle.target_cfg,
                                  jnp.asarray(prompt)[None], n))[0]


def _mixed_requests(vocab, seed=0):
    """Mixed prompt lengths AND budgets — impossible for the old
    uniform-length wave engine to serve in one allocation."""
    rng = np.random.default_rng(seed)
    plens = (8, 11, 8, 9, 10)
    wants = (6, 14, 9, 5, 11)
    prompts = [rng.integers(0, vocab, size=p).astype(np.int32)
               for p in plens]
    return prompts, wants


# ------------------------------------------------------------ tentpole -----
def test_mixed_budget_refill_parity_vs_generate(bundle):
    """Per-request outputs through refill batching == standalone greedy
    decoding of each request (token identity, acceptance criterion #1)."""
    prompts, wants = _mixed_requests(bundle.target_cfg.vocab_size)
    eng = ServingEngine(bundle, batch_size=2)
    for p, n in zip(prompts, wants):
        eng.submit(p, max_new=n)
    stats = eng.run()
    assert stats["waves"] == 1          # refill kept one allocation busy
    assert stats["refills"] == len(prompts) - 2
    assert len(eng.done) == len(prompts)
    for r in sorted(eng.done, key=lambda r: r.uid):
        assert r.out.shape == (r.max_new,)
        assert np.array_equal(r.out, _ref(bundle, prompts[r.uid],
                                          r.max_new)), r.uid
    # engine-level parity with the host generate() loop on one request
    g = pl.generate(bundle, jnp.asarray(prompts[0])[None],
                    max_new=wants[0], key=jax.random.PRNGKey(5))
    assert np.array_equal(np.asarray(g["tokens"])[0],
                          sorted(eng.done, key=lambda r: r.uid)[0].out)


def test_refill_preserves_other_rows(bundle):
    """Adopting a new request into a retired slot must not perturb the
    still-running rows' outputs (slot isolation)."""
    v = bundle.target_cfg.vocab_size
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, v, size=8).astype(np.int32) for _ in range(3)]
    wants = [18, 4, 4]                  # row 1 retires early, uid 2 adopts it
    eng = ServingEngine(bundle, batch_size=2)
    for p, n in zip(prompts, wants):
        eng.submit(p, max_new=n)
    stats = eng.run()
    assert stats["waves"] == 1 and stats["refills"] == 1
    for r in eng.done:
        assert np.array_equal(r.out, _ref(bundle, prompts[r.uid],
                                          r.max_new)), r.uid


def test_early_exit_and_refill_reduce_wasted_row_cycles(bundle):
    """Same traffic, same outputs — strictly fewer wasted row-cycles with
    early-exit + refill than with legacy all-or-nothing waves."""
    # one long request + sustained short traffic: the legacy wave pairs the
    # long budget with a short one and idles, refill keeps the short slot fed
    rng = np.random.default_rng(1)
    v = bundle.target_cfg.vocab_size
    wants = [20, 4, 4, 4, 4, 4]
    prompts = [rng.integers(0, v, size=p).astype(np.int32)
               for p in (10, 8, 9, 8, 11, 8)]

    def serve(early_exit, refill):
        eng = ServingEngine(bundle, batch_size=2, early_exit=early_exit,
                            refill=refill)
        for p, n in zip(prompts, wants):
            eng.submit(p, max_new=n)
        return eng, eng.run()

    eng_new, s_new = serve(True, True)
    eng_old, s_old = serve(False, False)
    by_uid = lambda e: sorted(e.done, key=lambda r: r.uid)  # noqa: E731
    for a, b in zip(by_uid(eng_new), by_uid(eng_old)):
        assert np.array_equal(a.out, b.out), a.uid
    assert s_new["tokens"] == s_old["tokens"]       # equal token output
    assert s_new["wasted_row_cycles"] < s_old["wasted_row_cycles"]


# ------------------------------------------------- satellite: stats fixes --
def test_alpha_and_token_stats_match_hand_computed(bundle):
    """alpha must be recomputable from the per-cycle (active, n_out) log:
    finished rows must not count in the denominator, and tokens must count
    what was actually committed per request."""
    prompts, wants = _mixed_requests(bundle.target_cfg.vocab_size, seed=2)
    eng = ServingEngine(bundle, batch_size=2)
    log = []
    orig = eng._cycle

    def recording_cycle(s, k):
        s2, out = orig(s, k)
        log.append((np.asarray(s.active).copy(),
                    np.asarray(out["n_out"]).copy()))
        return s2, out

    eng._cycle = recording_cycle
    for p, n in zip(prompts, wants):
        eng.submit(p, max_new=n)
    stats = eng.run()

    num = sum(int(n_out[act].sum()) for act, n_out in log)
    den = sum(int(act.sum()) for act, n_out in log)
    assert den < sum(len(a) for a, _ in log)    # some rows were masked
    assert stats["alpha"] == pytest.approx(num / den)
    # every request finished normally => committed exactly its budget
    assert stats["tokens"] == sum(wants)
    # masked rows commit nothing, so the active-row sum is the total sum
    assert num == sum(int(n_out.sum()) for _, n_out in log)


def test_finished_rows_commit_nothing(bundle):
    """Regression for the accounting bugs: a finished row's n_out is 0 with
    early-exit on, so neither alpha nor the output buffers move."""
    v = bundle.target_cfg.vocab_size
    rng = np.random.default_rng(5)
    eng = ServingEngine(bundle, batch_size=2, refill=False)
    eng.submit(rng.integers(0, v, size=8).astype(np.int32), max_new=4)
    eng.submit(rng.integers(0, v, size=8).astype(np.int32), max_new=20)
    seen = []
    orig = eng._cycle

    def recording_cycle(s, k):
        s2, out = orig(s, k)
        seen.append((np.asarray(s.active).copy(),
                     np.asarray(out["n_out"]).copy()))
        return s2, out

    eng._cycle = recording_cycle
    eng.run()
    masked = [(a, n) for a, n in seen if not a.all()]
    assert masked, "short request never went inactive"
    for act, n_out in masked:
        assert (n_out[~act] == 0).all()


def test_max_new_one_burst_needs_no_decode_cycles(bundle):
    """Requests satisfied by the prefill alone (max_new <= 1) retire and
    refill without paying a decode cycle."""
    v = bundle.target_cfg.vocab_size
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, v, size=8).astype(np.int32)
               for _ in range(4)]
    eng = ServingEngine(bundle, batch_size=2)
    for p in prompts:
        eng.submit(p, max_new=1)
    stats = eng.run()
    assert len(eng.done) == 4
    assert stats["cycles"] == 0 and stats["wasted_row_cycles"] == 0
    assert stats["tokens"] == 4
    for r in eng.done:
        assert np.array_equal(r.out, _ref(bundle, prompts[r.uid], 1)), r.uid


# ------------------------------------------------- satellite: FIFO waves ---
def test_fifo_no_starvation_by_prompt_length(bundle):
    """A long-prompt request submitted first must be served first even when
    shorter prompts keep arriving (the old length-sort starved it)."""
    v = bundle.target_cfg.vocab_size
    rng = np.random.default_rng(7)
    eng = ServingEngine(bundle, batch_size=1)
    long_uid = eng.submit(rng.integers(0, v, size=16).astype(np.int32),
                          max_new=4)
    for _ in range(3):
        eng.submit(rng.integers(0, v, size=6).astype(np.int32), max_new=4)
    eng.run()
    assert eng.done[0].uid == long_uid
    # and overall completion order is FIFO for equal budgets
    assert [r.uid for r in eng.done] == sorted(r.uid for r in eng.done)


# ------------------------------------ satellite: on-device early exit ------
def test_ondevice_early_exit_token_identity(bundle):
    """generate_ondevice with and without per-example masking is
    token-identical (and cycle-identical) for the same key."""
    prompts = jax.random.randint(jax.random.PRNGKey(3), (3, 8), 0,
                                 bundle.target_cfg.vocab_size)
    on = pl.generate_ondevice(bundle, prompts, max_new=16,
                              key=jax.random.PRNGKey(7), early_exit=True)
    off = pl.generate_ondevice(bundle, prompts, max_new=16,
                               key=jax.random.PRNGKey(7), early_exit=False)
    assert np.array_equal(np.asarray(on["tokens"]),
                          np.asarray(off["tokens"]))
    assert on["n_cycles"] == off["n_cycles"]


def test_ondevice_early_exit_freezes_finished_rows(bundle):
    """With mixed effective budgets the masked rows' state stops advancing:
    host-loop masking and the on-device while_loop agree on alpha too."""
    prompts = jax.random.randint(jax.random.PRNGKey(4), (3, 8), 0,
                                 bundle.target_cfg.vocab_size)
    host = pl.generate(bundle, prompts, max_new=16,
                       key=jax.random.PRNGKey(9), collect_stats=False,
                       early_exit=True)
    dev = pl.generate_ondevice(bundle, prompts, max_new=16,
                               key=jax.random.PRNGKey(9), early_exit=True)
    assert np.array_equal(host["tokens"], np.asarray(dev["tokens"]))
    assert host["n_cycles"] == dev["n_cycles"]
    assert host["alpha"] == pytest.approx(dev["alpha"])


# ----------------------------------------------- state-level primitives ----
def test_prefill_row_adopts_without_touching_neighbors(bundle):
    """adopt_row/prefill_row splice exactly one row of every cache."""
    v = bundle.target_cfg.vocab_size
    prompts = jax.random.randint(jax.random.PRNGKey(3), (3, 8), 0, v)
    state = pl.engine_init(bundle, 3, 64)
    state = pl.prefill(bundle, state, prompts)
    newp = jax.random.randint(jax.random.PRNGKey(8), (12,), 0, v)
    st2 = prefill_row(bundle, state, 1, newp, key=jax.random.PRNGKey(11))
    assert int(st2.length[1]) == 12
    assert [int(st2.length[i]) for i in (0, 2)] == \
        [int(state.length[i]) for i in (0, 2)]
    assert np.array_equal(np.asarray(st2.anchor)[[0, 2]],
                          np.asarray(state.anchor)[[0, 2]])
    # the adopted row's anchor equals a standalone prefill's first token
    ref = _ref(bundle, newp, 1)
    assert int(st2.anchor[1]) == int(ref[0])
    # feature caches spliced row-wise
    for feat, old in ((st2.d1_feat, state.d1_feat),
                      (st2.d2_feat, state.d2_feat)):
        assert np.array_equal(np.asarray(feat["k"][:, 0]),
                              np.asarray(old["k"][:, 0]))
        assert not np.array_equal(np.asarray(feat["k"][:, 1]),
                                  np.asarray(old["k"][:, 1]))


def test_decode_cycle_inactive_row_is_frozen(bundle):
    """A masked row keeps length, anchor, and caches bit-identical through
    a decode cycle while active rows advance."""
    v = bundle.target_cfg.vocab_size
    prompts = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0, v)
    state = pl.engine_init(bundle, 2, 64)
    state = pl.prefill(bundle, state, prompts)
    state = state.replace(active=jnp.asarray([True, False]))
    state2, out = pl.decode_cycle(bundle, state, jax.random.PRNGKey(1),
                                  collect_stats=False)
    n_out = np.asarray(out["n_out"])
    assert n_out[0] >= 1 and n_out[1] == 0
    assert int(state2.length[1]) == int(state.length[1])
    assert int(state2.length[0]) > int(state.length[0])
    assert int(state2.anchor[1]) == int(state.anchor[1])
    assert np.array_equal(np.asarray(state2.d1_feat["k"][:, 1]),
                          np.asarray(state.d1_feat["k"][:, 1]))
    assert (np.asarray(out["tokens"])[1] == 0).all()


def test_serving_state_replay_backend_smoke():
    """Early-exit masking also holds for the branch-batched state-replay
    verifier (recurrent target): outputs match per-request greedy."""
    tcfg = tiny_target(vocab=43, dtype="float32", layer_pattern=("rwkv",),
                       rwkv_head_dim=16)
    dcfg = tiny_drafter(vocab=43, gamma=4, dtype="float32", target_cfg=tcfg)
    tp = lm.lm_init(jax.random.PRNGKey(0), tcfg)
    d1 = drafter_init(jax.random.PRNGKey(1), dcfg)
    d2 = drafter_init(jax.random.PRNGKey(2), dcfg)
    spec = SpecConfig(gamma=4, top_k_branches=2, mode="d2sd")
    b = pl.SpecBundle(tcfg, dcfg, dcfg, spec, tp, d1, d2)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 43, size=6).astype(np.int32)
               for _ in range(3)]
    wants = [4, 8, 6]
    eng = ServingEngine(b, batch_size=2)
    for p, n in zip(prompts, wants):
        eng.submit(p, max_new=n)
    eng.run()
    assert len(eng.done) == 3
    for r in eng.done:
        assert np.array_equal(r.out, _ref(b, prompts[r.uid], r.max_new)), \
            r.uid
