"""Distribution-layer tests on a small in-process host mesh (subprocess: the
main test process keeps 1 device; these spawn `python -c` with
XLA_FLAGS=--xla_force_host_platform_device_count=8)."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_spdecode_matches_local():
    """KV-sequence-sharded decode attention == single-device reference."""
    _run(r"""
import jax, jax.numpy as jnp, numpy as np
from repro.distributed import sharding as sh
from repro.distributed.spdecode import sharded_cache_attend
from repro.launch.mesh import make_mesh
from repro.models.blocks import _attend_cache_plus_block

mesh = make_mesh(data=2, model=4)
b, tq, hq, hkv, s, d = 2, 6, 4, 2, 64, 16
ks = jax.random.split(jax.random.PRNGKey(0), 5)
q  = jax.random.normal(ks[0], (b, tq, hq, d))
ck = jax.random.normal(ks[1], (b, s, hkv, d))
cv = jax.random.normal(ks[2], (b, s, hkv, d))
bk = jax.random.normal(ks[3], (b, tq, hkv, d))
bv = jax.random.normal(ks[4], (b, tq, hkv, d))
cache_len = jnp.array([50, 30])
q_abs = cache_len[:, None] + jnp.arange(tq)[None, :]
mask = jnp.tril(jnp.ones((tq, tq), bool))

kk = jnp.concatenate([ck, bk], 1)
vv = jnp.concatenate([cv, bv], 1)
o2 = _attend_cache_plus_block(q, kk, vv, cache_cap=s, cache_len=cache_len,
                              q_abs=q_abs, window=None, extra_mask=mask,
                              attn_softcap=None, impl='dense', kv_chunk=64,
                              rolling=False)
with sh.use_sharding(mesh, dict(sh.LOGICAL_RULES, kv_seq="model")):
    # exact with fp32 merge payload
    o1 = jax.jit(lambda *a: sharded_cache_attend(
        *a, cache_len=cache_len, q_abs=q_abs, window=None,
        attn_softcap=None, blk_mask=mask, rolling=False,
        merge_dtype=jnp.float32))(q, ck, cv, bk, bv)
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32),
                               rtol=2e-5, atol=2e-5)
    # bf16 merge payload (the production default) within bf16 tolerance
    o3 = jax.jit(lambda *a: sharded_cache_attend(
        *a, cache_len=cache_len, q_abs=q_abs, window=None,
        attn_softcap=None, blk_mask=mask, rolling=False))(q, ck, cv, bk, bv)
    np.testing.assert_allclose(np.asarray(o3, np.float32),
                               np.asarray(o2, np.float32),
                               rtol=2e-2, atol=2e-2)
print('OK')
""")


def test_sharded_train_step_matches_single_device():
    """pjit'd train step on a 2x4 mesh == single-device step (same math)."""
    _run(r"""
import jax, jax.numpy as jnp, numpy as np
from repro.config.base import ModelConfig, OptimizerConfig
from repro.distributed import sharding as sh
from repro.launch.mesh import make_mesh
from repro.models import lm
from repro.optim import optimizers as opt_lib

cfg = ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                  d_ff=128, vocab_size=512, max_seq_len=64, remat=False,
                  dtype='float32')
hp = OptimizerConfig(lr=1e-3, total_steps=10)
params = lm.lm_init(jax.random.PRNGKey(0), cfg)
opt_init, opt_update = opt_lib.make_optimizer(hp)
opt = opt_init(params)
toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, 512)
batch = {'tokens': toks, 'labels': jnp.roll(toks, -1, 1),
         'mask': jnp.ones((8, 32), jnp.float32)}

def step(params, opt, batch):
    from repro.distributed.sharding import constrain_params
    params = constrain_params(params)
    loss, g = jax.value_and_grad(lambda p: lm.loss_fn(p, batch, cfg))(params)
    p2, o2, _ = opt_update(g, opt, params)
    return p2, loss

p_ref, l_ref = step(params, opt, batch)

mesh = make_mesh(data=2, model=4)
with sh.use_sharding(mesh, sh.LOGICAL_RULES, fsdp=True):
    shard_in = (sh.params_shardings(params, mesh),
                sh.params_shardings(opt, mesh),
                sh.params_shardings(batch, mesh))
    p_sh, l_sh = jax.jit(step, in_shardings=shard_in)(params, opt, batch)

assert abs(float(l_ref) - float(l_sh)) < 1e-4, (l_ref, l_sh)
d = max(float(jnp.abs(a - jax.device_get(b)).max())
        for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_sh)))
assert d < 1e-4, d
print('OK')
""")


def test_elastic_checkpoint_restore_across_meshes(tmp_path):
    """Save on a 2x4 mesh, restore onto 1x2 (elastic scale-down)."""
    _run(rf"""
import jax, jax.numpy as jnp, numpy as np
from repro.checkpoint.checkpointer import Checkpointer
from repro.config.base import ModelConfig
from repro.distributed import sharding as sh
from repro.launch.mesh import make_mesh
from repro.models import lm

cfg = ModelConfig(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                  d_ff=128, vocab_size=512, max_seq_len=64, remat=False)
params = lm.lm_init(jax.random.PRNGKey(0), cfg)
mesh_a = make_mesh(data=2, model=4)
with sh.use_sharding(mesh_a, sh.LOGICAL_RULES):
    sharded = jax.device_put(params, sh.params_shardings(params, mesh_a))
ck = Checkpointer(r'{tmp_path}')
ck.save(1, sharded)
mesh_b = make_mesh(data=1, model=2)
with sh.use_sharding(mesh_b, sh.LOGICAL_RULES):
    restored, _ = ck.restore(params, mesh=mesh_b)
for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
# restored leaves actually live on mesh_b
leaf = jax.tree.leaves(restored)[0]
assert leaf.sharding.mesh.shape == {{'data': 1, 'model': 2}}, leaf.sharding
print('OK')
""")


def test_moe_scatter_sharded_matches_local():
    _run(r"""
import jax, jax.numpy as jnp, numpy as np
from repro.config.base import ModelConfig, MoEConfig
from repro.distributed import sharding as sh
from repro.launch.mesh import make_mesh
from repro.models import moe as moe_lib

cfg = ModelConfig(num_layers=1, d_model=64, num_heads=4, num_kv_heads=2,
                  d_ff=128, vocab_size=97, dtype='float32',
                  moe=MoEConfig(num_experts=8, top_k=2, capacity_factor=2.0,
                                dispatch='scatter'))
p = moe_lib.moe_init(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 64))
y_ref = moe_lib.moe_apply(p, x, cfg)
mesh = make_mesh(data=2, model=4)
with sh.use_sharding(mesh, sh.LOGICAL_RULES):
    y_sh = jax.jit(lambda p, x: moe_lib.moe_apply(p, x, cfg),
                   in_shardings=(sh.params_shardings(p, mesh),
                                 sh.params_shardings(x, mesh)))(p, x)
np.testing.assert_allclose(np.asarray(y_ref), np.asarray(jax.device_get(y_sh)),
                           rtol=2e-4, atol=2e-4)
print('OK')
""")


def test_pipeline_parallel_matches_sequential():
    """GPipe pod-axis pipeline == sequential stage application."""
    _run(r"""
import jax, jax.numpy as jnp, numpy as np
from repro.distributed import sharding as sh
from repro.distributed.pipeline_parallel import pipeline_apply

from repro.distributed.compat import make_mesh
mesh = make_mesh((4, 2), ("pod", "data"))
S, M, mb, d = 4, 6, 3, 16
ks = jax.random.split(jax.random.PRNGKey(0), 2)
w = jax.random.normal(ks[0], (S, d, d)) * 0.3
xs = jax.random.normal(ks[1], (M, mb, d))

def stage(wi, x):
    return jnp.tanh(x @ wi["w"])

ref = xs
for s in range(S):
    ref = jnp.tanh(ref @ w[s])

with sh.use_sharding(mesh, sh.LOGICAL_RULES):
    out = jax.jit(lambda w, xs: pipeline_apply(stage, {"w": w}, xs))(w, xs)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5,
                           atol=1e-5)
print('OK')
""")


def test_compressed_grad_allreduce_error_feedback():
    """int8+EF gradient all-reduce: mean within quant tolerance and the EF
    residual shrinks the bias across steps."""
    _run(r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.distributed.collectives import (compressed_grad_allreduce,
                                           init_error_state)

from repro.distributed.compat import make_mesh, shard_map
mesh = make_mesh((8,), ("data",))
g_global = jax.random.normal(jax.random.PRNGKey(0), (8, 1000)) \
    * jnp.logspace(-3, 0, 1000)[None]
true_mean = g_global.mean(0)

def step(g_shard, e):
    return compressed_grad_allreduce({"g": g_shard}, {"g": e}, axis="data")

f = jax.jit(shard_map(step, mesh=mesh, in_specs=(P("data"), P("data")),
                      out_specs=(P("data"), P("data")),
                      check_vma=False))
e = jnp.zeros((8, 1000))
mean, e2 = f(g_global, e)
got = np.asarray(mean["g"])[0]
rel = np.abs(got - np.asarray(true_mean)).max() / np.abs(true_mean).max()
assert rel < 0.02, rel
# error feedback: residual is bounded by one quantization step
assert float(jnp.abs(e2["g"]).max()) < float(jnp.abs(g_global).max()) / 100
print('OK')
""")
