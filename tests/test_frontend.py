"""Async serving front-end: overlapped scheduling, open-loop traffic,
and per-request SLA metrics.

Everything runs on a :class:`VirtualClock` (1 virtual second per decode
cycle), so replays are fully deterministic: token-identity and
cycle-count assertions compare exact integers, and the TTFT/TPOT tests
check exact arithmetic on hand-built schedules.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import SpecConfig
from repro.core import pipeline as pl
from repro.core import state as cs
from repro.core.drafter import drafter_init
from repro.models import kvcache as kvc
from repro.models import lm
from repro.serving.engine import ServingEngine
from repro.serving.frontend import OverlappedFrontend, ReplayDriver, SyncReplay
from repro.serving.metrics import (MetricsRecorder, RequestTiming,
                                   VirtualClock, percentile, summarize)
from repro.serving.traffic import Arrival, bursty_trace, make_trace, \
    poisson_trace

from conftest import tiny_target, tiny_drafter, pure_greedy

GAMMA = 6
VOCAB = 61


@pytest.fixture(scope="module")
def bundle():
    tcfg = tiny_target(vocab=VOCAB, dtype="float32")
    dcfg = tiny_drafter(vocab=VOCAB, gamma=GAMMA, dtype="float32",
                        target_cfg=tcfg)
    tp = lm.lm_init(jax.random.PRNGKey(0), tcfg)
    d1 = drafter_init(jax.random.PRNGKey(1), dcfg)
    d2 = drafter_init(jax.random.PRNGKey(2), dcfg)
    spec = SpecConfig(gamma=GAMMA, top_k_branches=2, mode="d2sd")
    return pl.SpecBundle(tcfg, dcfg, dcfg, spec, tp, d1, d2)


def _ref(bundle, prompt, n):
    return np.asarray(pure_greedy(bundle.target_params, bundle.target_cfg,
                                  jnp.asarray(prompt)[None], n))[0]


def _engine(bundle, batch=3, install_s=0.25, **kw):
    clock = VirtualClock(cycle_s=1.0, install_s=install_s)
    rec = MetricsRecorder(clock)
    return ServingEngine(bundle, batch_size=batch, seed=0,
                         cache_impl="paged", page_size=8, pool_pages=64,
                         bucket_sizes=(8, 16), clock=clock,
                         recorder=rec, **kw)


def _outs(eng):
    return {r.uid: r.out.tolist() for r in eng.done}


# ------------------------------------------------------ metrics: exact -----
def test_percentile_nearest_rank_exact():
    xs = [5.0, 1.0, 3.0, 2.0, 4.0]
    assert percentile(xs, 50) == 3.0
    assert percentile(xs, 90) == 5.0
    assert percentile(xs, 99) == 5.0
    assert percentile(xs, 1) == 1.0
    assert percentile([7.0], 99) == 7.0
    s = summarize(xs)
    assert (s["p50"], s["max"], s["mean"]) == (3.0, 5.0, 3.0)
    empty = summarize([])
    assert empty["p50"] == 0.0 and empty["p99"] == 0.0


def test_ttft_tpot_exact_on_hand_schedule():
    """Recorder arithmetic on a hand-driven event sequence."""
    clock = VirtualClock(cycle_s=1.0, install_s=0.25)
    rec = MetricsRecorder(clock)
    rec.on_arrival(0, t=2.0)            # client sent at t=2.0
    clock.advance(3.5)                  # scheduler picks it up at 3.5
    rec.on_admit(0)
    rec.on_first_token(0)               # prefill anchor at admission
    clock.advance(6.5)                  # decode until t=10.0
    rec.on_done(0, n_tokens=5)
    (r,) = rec.completed()
    assert r.ttft == 1.5                # 3.5 - 2.0
    assert r.queue_wait == 1.5
    assert r.tpot == 6.5 / 4            # (10.0 - 3.5) / (5 - 1)
    assert r.e2e == 8.0                 # 10.0 - 2.0
    # single-token request: TPOT degenerates to 0, never divides by zero
    rec.on_arrival(1, t=10.0)
    rec.on_admit(1)
    rec.on_first_token(1)
    rec.on_done(1, n_tokens=1)
    assert rec.requests[1].tpot == 0.0
    summ = rec.summary()
    assert summ["n_requests"] == 2
    assert summ["ttft"]["max"] == 1.5


def test_virtual_clock_charges_costs():
    clock = VirtualClock(cycle_s=1.0, install_s=0.25)
    assert clock.now() == 0.0
    clock.tick("cycle")
    clock.tick("install", 2)
    assert clock.now() == 1.5
    clock.wait_until(5.0)
    assert clock.now() == 5.0
    clock.wait_until(1.0)               # never goes backwards
    assert clock.now() == 5.0


# ----------------------------------------------------------- traffic -------
def test_traffic_deterministic_and_bounded():
    kw = dict(rate=2.0, duration=10.0, seed=4, prompt_lens=(6, 9),
              max_new=(3, 12), vocab=VOCAB)
    a = poisson_trace(**kw)
    b = poisson_trace(**kw)
    assert len(a) == len(b) > 0
    for x, y in zip(a, b):
        assert x.t == y.t and x.max_new == y.max_new
        assert np.array_equal(x.prompt, y.prompt)
    assert all(0 < x.t < 10.0 for x in a)
    assert all(x.t <= y.t for x, y in zip(a, a[1:]))
    assert {x.max_new for x in a} <= {3, 12}
    assert all(x.prompt.min() >= 0 and x.prompt.max() < VOCAB for x in a)
    c = poisson_trace(**{**kw, "seed": 5})
    assert [x.t for x in c] != [x.t for x in a]
    d = bursty_trace(**kw)
    assert [x.t for x in d] != [x.t for x in a]    # different process
    assert make_trace("bursty", 2.0, 10.0, seed=4, prompt_lens=(6, 9),
                      max_new=(3, 12), vocab=VOCAB)[0].t == d[0].t
    with pytest.raises(ValueError):
        make_trace("lumpy", 1.0, 1.0)


# ------------------------------------------- replay: token identity --------
def test_replay_token_identity_and_sla(bundle):
    """Overlapped and sync replays of a seeded poisson trace produce
    identical per-request tokens, equal to standalone greedy decoding;
    the SLA summary is emitted and internally consistent."""
    trace = poisson_trace(rate=0.7, duration=10.0, seed=1,
                          prompt_lens=(6, 9), max_new=(3, 7), vocab=VOCAB)
    assert len(trace) >= 3
    eng_o = _engine(bundle)
    st_o = OverlappedFrontend(eng_o, trace).run()
    eng_s = _engine(bundle)
    st_s = SyncReplay(eng_s, trace).run()
    assert _outs(eng_o) == _outs(eng_s)
    assert len(eng_o.done) == len(trace)
    by_uid = {r.uid: r for r in eng_o.done}
    for uid, a in enumerate(trace):     # submit order == trace order
        assert np.array_equal(by_uid[uid].out,
                              _ref(bundle, a.prompt, a.max_new)), uid
    for st in (st_o, st_s):
        sla = st["sla"]
        assert sla["n_requests"] == len(trace)
        assert sla["ttft"]["p50"] > 0.0
        assert sla["ttft"]["p50"] <= sla["ttft"]["p90"] <= sla["ttft"]["p99"]
        assert sla["e2e"]["max"] >= sla["ttft"]["max"]
    # overlap may not win on light poisson load, but it must never lose
    assert st_o["engine_cycles"] <= st_s["engine_cycles"]


# --------------------------------------------- replay: structural win ------
def test_overlap_fewer_cycles_on_hand_built_burst(bundle):
    """The canonical overlap scenario, hand-built (no randomness): a
    long request anchors the wave, its co-admitted shorts retire into a
    momentarily empty queue, then a burst lands mid-wave. The sync
    baseline admits the burst only at the long request's retire (slots
    idle until the wave drains); the overlapped front-end admits it one
    cycle later — strictly fewer engine cycles, identical tokens."""
    rng = np.random.default_rng(0)

    def arr(t, plen, max_new):
        return Arrival(t=t, prompt=rng.integers(
            3, VOCAB, size=plen).astype(np.int32), max_new=max_new)

    trace = [arr(0.4, 8, 30), arr(0.45, 8, 2), arr(0.5, 8, 2),
             # burst lands while only the long request is still running
             arr(4.4, 8, 2), arr(4.5, 8, 2), arr(4.6, 8, 3)]
    rng2 = np.random.default_rng(0)     # identical prompts for both runs
    trace2 = [Arrival(t=a.t, prompt=rng2.integers(
        3, VOCAB, size=8).astype(np.int32), max_new=a.max_new)
        for a in trace]
    eng_o = _engine(bundle)
    st_o = OverlappedFrontend(eng_o, trace).run()
    eng_s = _engine(bundle)
    st_s = SyncReplay(eng_s, trace2).run()
    assert _outs(eng_o) == _outs(eng_s)
    assert st_o["engine_cycles"] < st_s["engine_cycles"], (
        st_o["engine_cycles"], st_s["engine_cycles"])
    # the overlapped run re-used the long request's wave for the burst
    assert st_o["refills"] >= 3


@pytest.mark.slow
def test_overlap_fewer_cycles_on_seeded_bursty(bundle):
    """Randomized end-to-end version of the structural win (slow: long
    MMPP replay through both drivers)."""
    trace = bursty_trace(rate=1.0, duration=20.0, seed=3, calm_scale=0.3,
                         burst_scale=5.0, mean_dwell=5.0, prompt_lens=(8,),
                         max_new=(4, 28), vocab=VOCAB)
    eng_o = _engine(bundle, batch=4)
    st_o = OverlappedFrontend(eng_o, trace).run()
    eng_s = _engine(bundle, batch=4)
    st_s = SyncReplay(eng_s, trace).run()
    assert _outs(eng_o) == _outs(eng_s)
    assert st_o["engine_cycles"] < st_s["engine_cycles"], (
        st_o["engine_cycles"], st_s["engine_cycles"])


# -------------------------------------------------- queue-depth timeline ---
def test_queue_depth_matches_reference_simulator(bundle):
    """The sampled queue-depth timeline equals an independent
    event-count reconstruction: depth(t) = #arrivals<=t - #admits<t.

    The driver samples at the pump instant, BEFORE that iteration's
    admissions — every due arrival is already in the queue and every
    admission stamped at or after the sample time has not popped it yet,
    so the equality is exact at every sample (strict inequality on the
    admit side).
    """
    trace = poisson_trace(rate=0.5, duration=12.0, seed=2,
                          prompt_lens=(6,), max_new=(3, 4), vocab=VOCAB)
    eng = _engine(bundle)
    OverlappedFrontend(eng, trace).run()
    rec = eng.recorder
    assert rec.queue_depth, "no queue-depth samples recorded"
    assert any(d > 0 for _, d in rec.queue_depth), "trace never queued"
    arrivals = sorted(a.t for a in trace)
    admits = sorted(r.t_admit for r in rec.requests.values()
                    if r.t_admit is not None)
    for t, depth in rec.queue_depth:
        ref = (sum(1 for x in arrivals if x <= t)
               - sum(1 for x in admits if x < t))
        assert depth == ref, (t, depth, ref)


# ----------------------------------------------------- batched installs ----
def test_batched_install_collapses_same_bucket_group(bundle):
    """Same-length-bucket co-admissions dispatch ONE batched install_rows
    call; per-request tokens equal standalone greedy decoding."""
    rng = np.random.default_rng(7)
    prompts = [rng.integers(3, VOCAB, size=7).astype(np.int32)
               for _ in range(3)]
    eng = _engine(bundle, batch=3)
    for p in prompts:
        eng.submit(p, max_new=4)
    eng.start_wave()
    while eng.wave is not None:
        eng.step()
    assert eng.stats["installs"] == 3
    assert eng.stats["install_calls"] == 1      # one batch-3 dispatch
    for r in eng.done:
        assert np.array_equal(r.out, _ref(bundle, prompts[r.uid], 4)), r.uid


def test_batched_install_matches_singles_path(bundle):
    """The batched install path is token-identical to the per-request
    fallback (bucketing off forces exact-length single installs)."""
    rng = np.random.default_rng(8)
    prompts = [rng.integers(3, VOCAB, size=n).astype(np.int32)
               for n in (6, 7, 9)]    # distinct lengths: one shared
    #                                   bucket batches, exact-length
    #                                   installs cannot

    def serve(bucket_sizes):
        clock = VirtualClock()
        eng = ServingEngine(bundle, batch_size=3, seed=0,
                            cache_impl="paged", page_size=8,
                            pool_pages=64, bucket_sizes=bucket_sizes,
                            clock=clock, recorder=MetricsRecorder(clock))
        for p in prompts:
            eng.submit(p, max_new=5)
        eng.run()
        return eng

    batched = serve((16,))
    singles = serve(None)
    assert _outs(batched) == _outs(singles)
    assert batched.stats["install_calls"] == 1
    assert singles.stats["install_calls"] == 3


# ------------------------------------------- sentinel + retained pools -----
def test_page_sentinel_is_growth_stable():
    """The unallocated-page marker is a constant, not n_pages: growing
    the pool can never make an old sentinel alias a real page."""
    assert kvc.PAGE_SENTINEL == np.iinfo(np.int32).max
    pool = kvc.PagePool(8, 4)
    pages = pool.alloc(2)
    t = pool.row_table(pages, 5)
    assert list(t[:2]) == pages
    assert (t[2:] == kvc.PAGE_SENTINEL).all()
    # any conceivable pool growth stays below the sentinel
    assert kvc.PAGE_SENTINEL > 10 ** 9


def test_engine_init_adopts_retained_pool_buffers(bundle):
    """engine_init(pools=...) must alias the captured device buffers —
    the borrowed-pool contract is zero-copy adoption, not a reload."""
    table = np.full((2, 4), kvc.PAGE_SENTINEL, np.int32)
    s0 = pl.engine_init(bundle, 2, 32, cache_impl="paged", page_size=8,
                        pool_pages=16, page_table=table)
    pools = cs.capture_pools(s0)
    assert pools, "paged state captured no pool buffers"
    s1 = pl.engine_init(bundle, 2, 32, cache_impl="paged", page_size=8,
                        pool_pages=16, page_table=table, pools=pools)
    adopted = cs.capture_pools(s1)
    assert set(adopted) == set(pools)
    for name, (k, v) in pools.items():
        k2, v2 = adopted[name]
        assert k2 is k and v2 is v, f"{name} was copied, not adopted"


def test_start_wave_width_builds_idle_rows(bundle):
    """Open-loop waves reserve idle rows for mid-flight admission: one
    visible request still yields a full-width wave, and the idle rows
    are claimable by admit_idle."""
    eng = _engine(bundle, batch=3)
    rng = np.random.default_rng(9)
    eng.submit(rng.integers(3, VOCAB, size=6).astype(np.int32), max_new=8)
    eng.start_wave(width=eng.batch_size)
    w = eng.wave
    assert len(w.requests) == 3
    assert sum(1 for r in w.requests if r is not None) == 1
    eng.submit(rng.integers(3, VOCAB, size=6).astype(np.int32), max_new=3)
    handle = eng.dispatch_cycle()
    assert eng.admit_idle() == 1        # idle row claimed mid-flight
    eng.complete_cycle(handle)
    while eng.wave is not None:
        eng.step()
    assert len(eng.done) == 2
