"""Beyond-paper adaptive-K scheduler: unit + property tests."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis; see _hypo_shim
    from _hypo_shim import given, settings, strategies as st

from repro.core import adaptive
from repro.core.confidence import boundary_posterior


def test_concentrated_posterior_needs_one_branch():
    r = jnp.array([[0.9, 0.01, 0.01, 0.01, 0.01]])
    assert int(adaptive.posterior_coverage_k(r, 0.85, 4)[0]) == 1


def test_diffuse_posterior_needs_many():
    r = jnp.ones((1, 8)) / 8
    assert int(adaptive.posterior_coverage_k(r, 0.85, 4)[0]) == 4


def test_skip_when_confident():
    conf = jnp.array([[0.99] * 6, [0.5] * 6])
    k = adaptive.choose_k(conf, boundary_posterior(conf))
    assert int(k[0]) == 0 and int(k[1]) > 0


@settings(max_examples=40, deadline=None)
@given(st.lists(st.floats(0.05, 0.99), min_size=3, max_size=12),
       st.floats(0.5, 0.95))
def test_choose_k_bounds_and_monotone_coverage(confs, cov):
    conf = jnp.array([confs])
    r = boundary_posterior(conf)
    k = adaptive.posterior_coverage_k(r, cov, 4)
    assert 1 <= int(k[0]) <= 4
    k_hi = adaptive.posterior_coverage_k(r, min(cov + 0.04, 0.99), 4)
    assert int(k_hi[0]) >= int(k[0])       # more coverage -> never fewer
