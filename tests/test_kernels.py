"""Per-kernel interpret-mode validation against the ref.py oracles,
sweeping shapes / dtypes / GQA groups / masks (assignment item c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels import flash_attention as fa

# These kernels TARGET TPU; on this CPU-only container they execute in
# Pallas interpret mode (see pytest.ini for the marker contract).
pytestmark = pytest.mark.pallas


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


CASES = [
    # (B, Hq, Hkv, Tq, Tkv, D, causal, window, softcap, dtype)
    (1, 2, 2, 128, 128, 64, True, None, None, jnp.float32),
    (2, 4, 2, 128, 256, 64, True, None, None, jnp.bfloat16),
    (1, 8, 2, 256, 256, 128, True, None, 50.0, jnp.bfloat16),
    (2, 2, 1, 128, 384, 64, True, 100, None, jnp.float32),
    (1, 4, 4, 64, 512, 64, False, None, None, jnp.float32),
    (2, 4, 2, 100, 300, 64, True, None, None, jnp.float32),  # ragged pads
]


@pytest.mark.parametrize("case", CASES)
def test_flash_forward_matches_ref(case):
    b, hq, hkv, tq, tkv, d, causal, window, cap, dtype = case
    ks = jax.random.split(jax.random.PRNGKey(hash(case) % 2 ** 31), 3)
    q = _rand(ks[0], (b, hq, tq, d), dtype)
    k = _rand(ks[1], (b, hkv, tkv, d), dtype)
    v = _rand(ks[2], (b, hkv, tkv, d), dtype)
    q_off = tkv - tq
    kv_len = tkv - 7
    o, lse = fa.flash_attention_fwd(
        q, k, v, causal=causal, q_offset=q_off, window=window, kv_len=kv_len,
        attn_softcap=cap, interpret=True)
    o_ref, lse_ref = ref.flash_attention_ref(
        q, k, v, causal=causal, q_offset=q_off, window=window, kv_len=kv_len,
        attn_softcap=cap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(lse_ref),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("case", [
    (1, 2, 2, 128, 128, 64, True, None, None, jnp.float32),
    (2, 4, 2, 128, 256, 64, True, None, None, jnp.float32),
    (1, 4, 2, 128, 128, 64, True, None, 30.0, jnp.float32),
    (1, 2, 1, 128, 256, 64, True, 64, None, jnp.float32),
])
def test_flash_backward_matches_autodiff(case):
    b, hq, hkv, tq, tkv, d, causal, window, cap, dtype = case
    ks = jax.random.split(jax.random.PRNGKey(hash(case) % 2 ** 31), 3)
    q = _rand(ks[0], (b, hq, tq, d), dtype)
    k = _rand(ks[1], (b, hkv, tkv, d), dtype)
    v = _rand(ks[2], (b, hkv, tkv, d), dtype)
    q_off = tkv - tq

    def f_kernel(q, k, v):
        o = ops.flash_attention(q, k, v, causal=causal, q_offset=q_off,
                                window=window, attn_softcap=cap,
                                interpret=True, layout="BHTD")
        return (o.astype(jnp.float32) ** 2).sum()

    def f_ref(q, k, v):
        o, _ = ref.flash_attention_ref(q, k, v, causal=causal,
                                       q_offset=q_off, window=window,
                                       attn_softcap=cap)
        return (o.astype(jnp.float32) ** 2).sum()

    g1 = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b_, np.float32),
                                   rtol=2e-3, atol=2e-3)


CASC_CASES = [
    # (B, Hq, Hkv, Tq, S, Tb, D, window, cap, rolling, dtype)
    (1, 2, 2, 16, 512, 16, 64, None, None, False, jnp.float32),
    (2, 4, 2, 76, 1024, 76, 64, None, None, False, jnp.bfloat16),
    (1, 8, 2, 32, 2048, 32, 128, None, 50.0, False, jnp.bfloat16),
    (2, 2, 1, 16, 512, 16, 64, 300, None, True, jnp.float32),
    (1, 4, 4, 8, 768, 8, 64, None, None, False, jnp.float32),
]


@pytest.mark.parametrize("case", CASC_CASES)
def test_cascade_matches_ref(case):
    b, hq, hkv, tq, s, tb, d, window, cap, rolling, dtype = case
    ks = jax.random.split(jax.random.PRNGKey(hash(case) % 2 ** 31), 6)
    q = _rand(ks[0], (b, hq, tq, d), dtype)
    ck = _rand(ks[1], (b, hkv, s, d), dtype)
    cv = _rand(ks[2], (b, hkv, s, d), dtype)
    bk = _rand(ks[3], (b, hkv, tb, d), dtype)
    bv = _rand(ks[4], (b, hkv, tb, d), dtype)
    cache_len = jnp.array([s - 5] + [s - 200] * (b - 1))[:b]
    # comb-ish positions: anchor + increasing depths
    q_abs = cache_len[:, None] + jnp.arange(tq)[None, :] % max(tb, 1)
    tree_mask = jnp.tril(jnp.ones((tq, tb), bool))  # chain-ish mask
    o = casc_call = None
    from repro.kernels.ops import cascade_attention
    o = cascade_attention(q, ck, cv, bk, bv, cache_len=cache_len,
                          q_abs=q_abs, tree_mask=tree_mask, window=window,
                          attn_softcap=cap, rolling=rolling, n_splits=4,
                          bk=256, interpret=True, layout="BHTD")
    o_ref = ref.cascade_attention_ref(
        q, ck, cv, bk, bv, cache_len=cache_len, q_abs=q_abs,
        tree_mask=tree_mask, window=window, attn_softcap=cap,
        rolling=rolling)
    tol = 2e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=tol, atol=tol)


# Ragged + sliding-window sweep at page-aligned and page-straddling cache
# lengths (the boundaries the paged layout makes interesting; bk=64 below
# doubles as the page size so "aligned" means a block/page boundary).
RAGGED_CASES = [
    # (cache_lens, window, rolling)
    ((512, 256), None, False),        # page-aligned, ragged batch
    ((505, 250), None, False),        # page-straddling, ragged batch
    ((512, 256), 96, False),          # aligned + sliding window
    ((505, 131), 96, False),          # straddling + sliding window
    ((505, 250), 200, True),          # straddling + window + rolling buffer
]


@pytest.mark.parametrize("case", RAGGED_CASES)
def test_cascade_ragged_window_boundaries(case):
    """Dense cascade kernel vs oracle on per-example cache lengths that sit
    exactly on / just off KV-block boundaries, with sliding windows."""
    cache_lens, window, rolling = case
    b, hq, hkv, tq, s, d = len(cache_lens), 4, 2, 10, 512, 64
    ks = jax.random.split(jax.random.PRNGKey(42), 5)
    q = _rand(ks[0], (b, hq, tq, d), jnp.float32)
    ck = _rand(ks[1], (b, hkv, s, d), jnp.float32)
    cv = _rand(ks[2], (b, hkv, s, d), jnp.float32)
    bk = _rand(ks[3], (b, hkv, tq, d), jnp.float32)
    bv = _rand(ks[4], (b, hkv, tq, d), jnp.float32)
    cache_len = jnp.asarray(cache_lens)
    q_abs = cache_len[:, None] + jnp.arange(tq)[None, :]
    tree_mask = jnp.tril(jnp.ones((tq, tq), bool))
    o = ops.cascade_attention(q, ck, cv, bk, bv, cache_len=cache_len,
                              q_abs=q_abs, tree_mask=tree_mask,
                              window=window, rolling=rolling, n_splits=4,
                              bk=64, interpret=True, layout="BHTD")
    o_ref = ref.cascade_attention_ref(
        q, ck, cv, bk, bv, cache_len=cache_len, q_abs=q_abs,
        tree_mask=tree_mask, window=window, rolling=rolling)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=3e-5, atol=3e-5)


# Rolling-buffer position recovery at ADVERSARIAL capacities: the modulus
# ``cap`` the kernel recovers absolute positions with must be the TRUE
# buffer capacity, not the split-padded extent — every capacity below is
# non-power-of-two and most are non-bk-aligned (bk=64), which is exactly
# where the old ``cap=s_pad`` plumbing recovered wrong positions.
ROLLING_CASES = [
    # (cap, window, cache_lens)  — lens mix pre-wrap (len <= cap) and
    # full wraparound (len > cap, every slot live and rolled)
    (97, 97, (40, 150)),          # prime cap, pre-wrap + wrapped
    (97, 50, (96, 300)),          # window < cap
    (100, 100, (100, 257)),       # len == cap boundary + deep wrap
    (131, 96, (70, 200)),         # prime, non-bk-aligned window
    (505, 505, (505, 711)),       # > bk, straddles 7.9 blocks
    (509, 200, (300, 1000)),      # prime > bk, deep wrap, small window
    (24, 24, (5, 30)),            # cap < bk (single sub-block)
]


@pytest.mark.parametrize("case", ROLLING_CASES)
def test_cascade_rolling_nonaligned_capacity_matches_ref(case):
    """Dense cascade kernel vs oracle over ROLLING buffers at
    non-block-aligned capacities x window sizes x ragged cache_len
    (including len > cap wraparound) — the tentpole bug regression."""
    cap, window, cache_lens = case
    b, hq, hkv, tq, d = len(cache_lens), 4, 2, 6, 32
    ks = jax.random.split(jax.random.PRNGKey(hash(case) % 2 ** 31), 5)
    q = _rand(ks[0], (b, hq, tq, d), jnp.float32)
    ck = _rand(ks[1], (b, hkv, cap, d), jnp.float32)
    cv = _rand(ks[2], (b, hkv, cap, d), jnp.float32)
    bkv = _rand(ks[3], (b, hkv, tq, d), jnp.float32)
    bvv = _rand(ks[4], (b, hkv, tq, d), jnp.float32)
    cache_len = jnp.asarray(cache_lens)
    q_abs = cache_len[:, None] + jnp.arange(tq)[None, :]
    tree_mask = jnp.tril(jnp.ones((tq, tq), bool))
    o = ops.cascade_attention(q, ck, cv, bkv, bvv, cache_len=cache_len,
                              q_abs=q_abs, tree_mask=tree_mask,
                              window=window, rolling=True, n_splits=4,
                              bk=64, interpret=True, layout="BHTD")
    o_ref = ref.cascade_attention_ref(
        q, ck, cv, bkv, bvv, cache_len=cache_len, q_abs=q_abs,
        tree_mask=tree_mask, window=window, rolling=True)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=3e-5, atol=3e-5)


def test_cascade_phase1_split_count_invariant():
    """cascade_phase1 pads the cache up to the requested split grid
    instead of degrading split-K: effective splits ==
    min(n_splits, ceil(S / bk)) even at prime-ish capacities (the old
    divisibility loop collapsed e.g. S=509, bk=64 to ONE split)."""
    from repro.kernels import cascade_attention as casc
    b, hq, hkv, tq, d = 1, 2, 2, 4, 32
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    for s, n_req, bk, want in [(509, 8, 64, 8),   # prime: used to be 1
                               (505, 4, 64, 4),   # non-aligned
                               (512, 8, 64, 8),   # aligned: unchanged
                               (100, 8, 64, 2),   # short cache clamps
                               (24, 4, 64, 1)]:   # cap < bk
        q = _rand(ks[0], (b, hq, tq, d), jnp.float32)
        ck = _rand(ks[1], (b, hkv, s, d), jnp.float32)
        cv = _rand(ks[2], (b, hkv, s, d), jnp.float32)
        acc, m, l = casc.cascade_phase1(
            q, ck, cv, cache_len=jnp.array([s]),
            q_abs=jnp.arange(tq)[None] + s, n_splits=n_req, bk=bk,
            interpret=True)
        got = acc.shape[2]
        assert got == want == min(n_req, -(-s // min(bk, s))), (
            s, n_req, bk, got, want)
        assert m.shape[2] == l.shape[2] == got


PAGED_CASES = [
    # (B, Hq, Hkv, Tq, page, mp, n_phys, cache_lens, window)
    (2, 4, 2, 12, 64, 8, 20, (512, 256), None),     # page-aligned
    (2, 4, 2, 12, 64, 8, 20, (505, 250), None),     # page-straddling
    (2, 4, 2, 12, 64, 8, 20, (505, 131), 100),      # straddling + window
    (1, 8, 2, 16, 128, 4, 7, (333,), None),         # GQA 4, odd pool
    (3, 2, 2, 8, 32, 6, 24, (192, 100, 65), 64),    # 3-way ragged + window
    (2, 4, 2, 8, 64, 7, 15, (410, 230), None),      # PRIME max_pages:
    # the table pads to keep 4-way split-K instead of collapsing to 1
]


@pytest.mark.parametrize("case", PAGED_CASES)
def test_cascade_paged_matches_ref(case):
    """Paged cascade kernel (scalar-prefetch page-table index_map) vs the
    gather-then-dense oracle, over shuffled disjoint page tables with
    unallocated sentinel tails."""
    b, hq, hkv, tq, page, mp, n_phys, cache_lens, window = case
    d = 64
    rng = np.random.default_rng(hash(case) % 2 ** 31)
    ks = jax.random.split(jax.random.PRNGKey(hash(case) % 2 ** 31), 5)
    q = _rand(ks[0], (b, hq, tq, d), jnp.float32)
    pk = _rand(ks[1], (n_phys, hkv, page, d), jnp.float32)
    pv = _rand(ks[2], (n_phys, hkv, page, d), jnp.float32)
    bk = _rand(ks[3], (b, hkv, tq, d), jnp.float32)
    bv = _rand(ks[4], (b, hkv, tq, d), jnp.float32)
    # disjoint shuffled page tables sized to each row's cache length;
    # unallocated logical pages carry the out-of-range sentinel
    perm = list(rng.permutation(n_phys))
    pt = np.full((b, mp), n_phys, np.int32)
    for i, cl in enumerate(cache_lens):
        need = -(-int(cl) // page)
        pt[i, :need] = [perm.pop() for _ in range(need)]
    cache_len = jnp.asarray(cache_lens)
    q_abs = cache_len[:, None] + jnp.arange(tq)[None, :]
    tree_mask = jnp.tril(jnp.ones((tq, tq), bool))
    o = ops.cascade_attention_paged(
        q, pk, pv, jnp.asarray(pt), bk, bv, cache_len=cache_len,
        q_abs=q_abs, tree_mask=tree_mask, window=window, n_splits=4,
        interpret=True, layout="BHTD")
    o_ref = ref.cascade_attention_paged_ref(
        q, pk, pv, jnp.asarray(pt), bk, bv, cache_len=cache_len,
        q_abs=q_abs, tree_mask=tree_mask, window=window)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=3e-5, atol=3e-5)


def test_cascade_paged_equals_engine_view():
    """Paged kernel on engine-layout pools == the model's decode read path
    (pool_view gather + attend_cache_plus_block) on the same paged state —
    ties the kernel to the storage subsystem that feeds it."""
    from repro.models import kvcache as kvc
    from repro.models.attention import attend_cache_plus_block
    b, hq, hkv, tq, page, mp, d = 2, 4, 2, 8, 32, 4, 64
    n_phys = b * mp
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    # engine storage layout: [P, page, Hkv, D]
    pk = _rand(ks[0], (n_phys, page, hkv, d), jnp.float32)
    pv = _rand(ks[1], (n_phys, page, hkv, d), jnp.float32)
    q = _rand(ks[2], (b, tq, hq, d), jnp.float32)        # BTHD
    bk = _rand(ks[3], (b, tq, hkv, d), jnp.float32)
    bv = _rand(ks[4], (b, tq, hkv, d), jnp.float32)
    pt = kvc.identity_page_table(b, mp)
    cache_len = jnp.array([mp * page - 5, 70])
    q_abs = cache_len[:, None] + jnp.arange(tq)[None, :]
    tree_mask = jnp.tril(jnp.ones((tq, tq), bool))

    o1 = ops.cascade_attention_paged(
        q, pk, pv, pt, bk, bv, cache_len=cache_len, q_abs=q_abs,
        tree_mask=tree_mask, n_splits=2, interpret=True, layout="BTHD")
    kk = jnp.concatenate([kvc.pool_view(pk, pt), bk], axis=1)
    vv = jnp.concatenate([kvc.pool_view(pv, pt), bv], axis=1)
    o2 = attend_cache_plus_block(
        q, kk, vv, cache_cap=mp * page, cache_len=cache_len, q_abs=q_abs,
        window=None, extra_mask=tree_mask, attn_softcap=None, impl="dense",
        kv_chunk=128, rolling=False)
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32),
                               rtol=3e-5, atol=3e-5)


def test_cascade_equals_engine_reference():
    """Cascade kernel == the engine's _attend_cache_plus_block on the same
    inputs (ties the kernel to the system that uses it)."""
    from repro.models.blocks import _attend_cache_plus_block
    b, hq, hkv, tq, s, d = 2, 4, 2, 12, 256, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = _rand(ks[0], (b, tq, hq, d), jnp.float32)
    ck = _rand(ks[1], (b, s, hkv, d), jnp.float32)
    cv = _rand(ks[2], (b, s, hkv, d), jnp.float32)
    bk = _rand(ks[3], (b, tq, hkv, d), jnp.float32)
    bv = _rand(ks[4], (b, tq, hkv, d), jnp.float32)
    cache_len = jnp.array([s - 3, s - 100])
    q_abs = cache_len[:, None] + jnp.arange(tq)[None, :]
    tree_mask = jnp.tril(jnp.ones((tq, tq), bool))

    o1 = ops.cascade_attention(q, ck, cv, bk, bv, cache_len=cache_len,
                               q_abs=q_abs, tree_mask=tree_mask,
                               interpret=True, n_splits=2, bk=128)
    kk = jnp.concatenate([ck, bk], axis=1)
    vv = jnp.concatenate([cv, bv], axis=1)
    o2 = _attend_cache_plus_block(
        q, kk, vv, cache_cap=s, cache_len=cache_len, q_abs=q_abs,
        window=None, extra_mask=tree_mask, attn_softcap=None, impl="dense",
        kv_chunk=128, rolling=False)
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32),
                               rtol=3e-5, atol=3e-5)


def test_cascade_paged_pos_stride_offset_shard_contract():
    """The position re-parameterization the kv_seq-sharded verify relies
    on (``distributed/spdecode.sharded_paged_cache_attend``): split every
    page's slots across two "shards" (shard i owns slots
    ``[i*page_loc, (i+1)*page_loc)`` of each page), run the paged phase-1
    kernel per shard with ``pos_stride=global page`` /
    ``pos_offset=i*page_loc``, LSE-merge the partials across shards, and
    the result must equal the dense cascade over the unsharded cache."""
    from repro.kernels import cascade_attention as casc
    b, hq, hkv, tq, d = 2, 4, 2, 6, 16
    page, mp, nsh = 8, 4, 2
    page_loc = page // nsh
    s = mp * page
    ks = jax.random.split(jax.random.PRNGKey(9), 5)
    q = _rand(ks[0], (b, hq, tq, d), jnp.float32)
    ck = _rand(ks[1], (b, hkv, s, d), jnp.float32)
    cv = _rand(ks[2], (b, hkv, s, d), jnp.float32)
    bk = _rand(ks[3], (b, hkv, tq, d), jnp.float32)
    bv = _rand(ks[4], (b, hkv, tq, d), jnp.float32)
    # ragged: row 1's live length leaves one shard of its tail page empty
    cache_len = jnp.array([s - 3, 17])
    q_abs = cache_len[:, None] + jnp.arange(tq)[None, :]
    tree_mask = jnp.tril(jnp.ones((tq, tq), bool))
    o_ref = ops.cascade_attention(
        q, ck, cv, bk, bv, cache_len=cache_len, q_abs=q_abs,
        tree_mask=tree_mask, n_splits=2, interpret=True, layout="BHTD")

    pt = (jnp.arange(b)[:, None] * mp
          + jnp.tile(jnp.arange(mp)[None], (b, 1))).astype(jnp.int32)
    parts = []
    for i in range(nsh):
        pool_k = np.zeros((b * mp, hkv, page_loc, d), np.float32)
        pool_v = np.zeros_like(pool_k)
        for bb in range(b):
            for pg in range(mp):
                sl = slice(pg * page + i * page_loc,
                           pg * page + (i + 1) * page_loc)
                pool_k[bb * mp + pg] = np.asarray(ck)[bb, :, sl]
                pool_v[bb * mp + pg] = np.asarray(cv)[bb, :, sl]
        parts.append(casc.cascade_phase1_paged(
            q, jnp.asarray(pool_k), jnp.asarray(pool_v), pt,
            cache_len=cache_len, q_abs=q_abs, n_splits=2,
            pos_stride=page, pos_offset=i * page_loc, interpret=True))
    # cross-shard merge = one more split-axis LSE merge (what the psum
    # merge in spdecode computes), folded into phase 2
    acc = jnp.concatenate([p[0] for p in parts], axis=2)
    m = jnp.concatenate([p[1] for p in parts], axis=2)
    l = jnp.concatenate([p[2] for p in parts], axis=2)
    o = casc._merge_with_tree_block(q, bk, bv, acc, m, l,
                                    tree_mask=tree_mask, attn_softcap=None,
                                    scale=d ** -0.5)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=3e-5, atol=3e-5)


# ---- attn_impl="pallas": end-to-end token parity with the gather path ----

def _parity_bundle(**tkw):
    from conftest import tiny_drafter, tiny_target
    from repro.config.base import SpecConfig
    from repro.core import pipeline as pl
    from repro.core.drafter import drafter_init
    from repro.models import lm
    tcfg = tiny_target(vocab=61, dtype="float32", **tkw)
    dcfg = tiny_drafter(vocab=61, gamma=6, dtype="float32", target_cfg=tcfg)
    tp = lm.lm_init(jax.random.PRNGKey(0), tcfg)
    d1 = drafter_init(jax.random.PRNGKey(1), dcfg)
    d2 = drafter_init(jax.random.PRNGKey(2), dcfg)
    spec = SpecConfig(gamma=6, mode="d2sd")
    return pl.SpecBundle(tcfg, dcfg, dcfg, spec, tp, d1, d2)


@pytest.mark.parametrize("cache_impl", ["paged", "dense"])
def test_attn_impl_token_parity_generate(cache_impl):
    """generate() tokens are identical between attn_impl="gather" and
    "pallas" (interpret mode) — the read path is a pure implementation
    choice, asserted on both paged and dense engines."""
    from repro.core import pipeline as pl
    bundle = _parity_bundle()
    prompts = jax.random.randint(jax.random.PRNGKey(4), (2, 7), 0, 61)
    outs = {}
    for impl in ("gather", "pallas"):
        res = pl.generate(pl.with_attn_impl(bundle, impl), prompts, 10,
                          key=jax.random.PRNGKey(7), cache_impl=cache_impl,
                          page_size=8)
        outs[impl] = np.asarray(res["tokens"]).tolist()
    assert outs["gather"] == outs["pallas"]


def test_attn_impl_token_parity_sliding_window_target():
    """Same parity on a mixed local/global target: paged global layers go
    through the paged kernel, sliding-window local layers through the
    DENSE kernel over their rolling buffers (true-capacity modulus,
    window=24 deliberately non-block-aligned), and the mix must still be
    token-identical end to end."""
    from repro.core import pipeline as pl
    bundle = _parity_bundle(layer_pattern=("local", "global"),
                            sliding_window=24)
    prompts = jax.random.randint(jax.random.PRNGKey(5), (2, 9), 0, 61)
    outs = {}
    for impl in ("gather", "pallas"):
        res = pl.generate(pl.with_attn_impl(bundle, impl), prompts, 10,
                          key=jax.random.PRNGKey(7), cache_impl="paged",
                          page_size=8)
        outs[impl] = np.asarray(res["tokens"]).tolist()
    assert outs["gather"] == outs["pallas"]


def test_attn_impl_token_parity_serving_ragged():
    """ServingEngine parity on mixed prompt lengths / budgets: per-row
    cache_len is genuinely ragged (page-straddling tails), and per-request
    tokens must match between read paths."""
    from repro.core import pipeline as pl
    from repro.serving.engine import ServingEngine
    bundle = _parity_bundle()
    rng = np.random.default_rng(11)
    reqs = [(rng.integers(3, 61, size=p).astype(np.int32), n)
            for p, n in [(11, 5), (5, 3), (8, 6), (6, 4)]]
    outs = {}
    for impl in ("gather", "pallas"):
        eng = ServingEngine(pl.with_attn_impl(bundle, impl), batch_size=2,
                            seed=0, cache_impl="paged", page_size=8)
        for p, n in reqs:
            eng.submit(p, max_new=n)
        eng.run()
        outs[impl] = {r.uid: r.out.tolist() for r in eng.done}
    assert outs["gather"] == outs["pallas"]
