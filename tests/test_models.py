"""Model substrate: every block family, decode-vs-full consistency, MoE
dispatch equivalence, RWKV chunked-vs-scan, attention impl equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis; see _hypo_shim
    from _hypo_shim import given, settings, strategies as st

from repro.config.base import ModelConfig, MoEConfig
from repro.models import lm, rwkv
from repro.models.attention import attend

BASE = dict(d_model=64, num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=97,
            max_seq_len=64, remat=False)


def _consistency(cfg, ctx_dim=0, t=12, tol=0.15):
    params = lm.lm_init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, t), 0,
                              cfg.vocab_size)
    ctx = (jax.random.normal(jax.random.PRNGKey(2), (2, 5, cfg.d_model),
                             jnp.bfloat16) if ctx_dim else None)
    out = lm.forward(params, toks, cfg, ctx=ctx)
    assert not jnp.isnan(out["logits"].astype(jnp.float32)).any()
    states = lm.init_states(cfg, 2, 32, ctx_len=5)
    o1 = lm.forward(params, toks[:, :-1], cfg, states=states, write_kv=True,
                    ctx=ctx)
    o2 = lm.forward(params, toks[:, -1:], cfg, states=o1["states"],
                    write_kv=False)
    d = jnp.abs(o2["logits"][:, -1].astype(jnp.float32)
                - out["logits"][:, -1].astype(jnp.float32)).max()
    assert d < tol, d


def test_dense_gqa():
    _consistency(ModelConfig(num_layers=4, qkv_bias=True, qk_norm=True,
                             **BASE))


def test_gemma2_like():
    _consistency(ModelConfig(num_layers=4, layer_pattern=("local", "global"),
                             sliding_window=8, use_post_norm=True,
                             attn_softcap=50.0, logit_softcap=30.0, **BASE))


def test_hybrid_tail():
    _consistency(ModelConfig(num_layers=5,
                             layer_pattern=("recurrent", "recurrent", "local"),
                             sliding_window=8, **BASE))


def test_rwkv_stack():
    _consistency(ModelConfig(num_layers=4, layer_pattern=("rwkv",),
                             rwkv_head_dim=16, **BASE))


def test_cross_attention():
    _consistency(ModelConfig(num_layers=4, cross_attn_every=2, **BASE),
                 ctx_dim=1)


@pytest.mark.parametrize("dispatch", ["einsum", "scatter"])
def test_moe(dispatch):
    # generous capacity: decode-vs-full consistency requires no drops
    _consistency(ModelConfig(
        num_layers=4, moe=MoEConfig(num_experts=4, top_k=2,
                                    capacity_factor=4.0, dispatch=dispatch),
        **BASE), tol=0.16)


def test_moe_dispatch_paths_agree():
    from repro.models import moe as moe_lib
    cfg = ModelConfig(num_layers=1, moe=MoEConfig(
        num_experts=4, top_k=2, capacity_factor=4.0), dtype="float32", **BASE)
    p = moe_lib.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y1 = moe_lib.moe_apply(p, x, cfg, dispatch="einsum")
    y2 = moe_lib.moe_apply(p, x, cfg, dispatch="scatter")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10 ** 6))
def test_rwkv_chunked_equals_scan(seed):
    b, t, h, dh = 2, 64, 2, 16
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (b, t, h, dh))
    k = jax.random.normal(ks[1], (b, t, h, dh))
    v = jax.random.normal(ks[2], (b, t, h, dh))
    # decay from the parameterization w = exp(-exp(x)) in the regime the
    # fp32 factorization supports (per-chunk cumulative decay < ~35 nats;
    # see time_mix_chunked docstring — the scan path covers the rest).
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (b, t, h, dh)) * 0.5 - 2.0))
    u = jax.random.normal(ks[4], (h, dh)) * 0.5
    s0 = jnp.zeros((b, h, dh, dh))
    o1, s1 = rwkv._time_mix_scan(r, k, v, w, u, s0)
    o2, s2 = rwkv.time_mix_chunked(r, k, v, w, u, s0, chunk=16)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-3,
                               atol=2e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-3,
                               atol=2e-3)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 4), st.integers(1, 3), st.booleans())
def test_attention_impls_agree(hq_mult, seed, causal):
    hkv = 2
    hq = hkv * hq_mult
    b, tq, tkv, dh = 2, 8, 24, 16
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, tq, hq, dh))
    k = jax.random.normal(ks[1], (b, tkv, hkv, dh))
    v = jax.random.normal(ks[2], (b, tkv, hkv, dh))
    kwargs = dict(causal=causal, q_offset=tkv - tq, window=None, kv_len=20)
    y1 = attend(q, k, v, impl="dense", **kwargs)
    y2 = attend(q, k, v, impl="chunked", kv_chunk=7, **kwargs)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-5,
                               atol=2e-5)


def test_snap_at_state_advance():
    """Replay with snap_at=n must equal stepping n tokens."""
    cfg = ModelConfig(num_layers=3, layer_pattern=("rwkv",), rwkv_head_dim=16,
                      dtype="float32", **BASE)
    p = lm.lm_init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 97)
    n_keep = jnp.array([3, 5])
    s0 = lm.init_states(cfg, 2, 32, dtype=jnp.float32)
    out = lm.forward(p, toks, cfg, states=s0, write_kv=True, snap_at=n_keep,
                     attend_cache_on_write=True)
    # reference: per-example prefix stepping
    for i, n in enumerate([3, 5]):
        si = lm.init_states(cfg, 1, 32, dtype=jnp.float32)
        oi = lm.forward(p, toks[i:i + 1, :n], cfg, states=si, write_kv=True)
        got = out["states"]["p0"]["tm_s"][0, i]
        ref = oi["states"]["p0"]["tm_s"][0, 0]
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
        assert int(out["states"]["length"][i]) == n
