"""Property tests for the prefix-tree machinery."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis; see _hypo_shim
    from _hypo_shim import given, settings, strategies as st

from repro.core import tree as T


def make_comb(g, k, seed=0, b=2):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    anchor = jax.random.randint(ks[0], (b,), 0, 50)
    trunk = jax.random.randint(ks[1], (b, g - 1), 0, 50)
    branch = jax.random.randint(ks[2], (b, k, g - 1), 0, 50)
    # distinct fork indices per example
    fork = jnp.stack([jax.random.permutation(
        jax.random.fold_in(ks[3], i), g - 1)[:k] for i in range(b)])
    return T.comb_tree(anchor, trunk, branch, fork, g), fork


@settings(max_examples=25, deadline=None)
@given(st.integers(3, 10), st.integers(1, 4))
def test_comb_structure(g, k):
    k = min(k, g - 1)
    tree, fork = make_comb(g, k, seed=g * 7 + k)
    parent = np.asarray(tree.parent)
    depth = np.asarray(tree.depth)
    valid = np.asarray(tree.valid)
    for b in range(parent.shape[0]):
        for n in range(tree.n):
            if not valid[b, n]:
                continue
            if n == 0:
                assert parent[b, n] == -1 and depth[b, n] == 0
            else:
                p = parent[b, n]
                assert valid[b, p], (b, n, p)
                assert depth[b, n] == depth[b, p] + 1


@settings(max_examples=25, deadline=None)
@given(st.integers(3, 8), st.integers(1, 3))
def test_ancestor_mask_closure(g, k):
    k = min(k, g - 1)
    tree, _ = make_comb(g, k, seed=g * 11 + k)
    m = np.asarray(T.ancestor_mask(tree))
    parent = np.asarray(tree.parent)
    valid = np.asarray(tree.valid)
    for b in range(m.shape[0]):
        for u in range(tree.n):
            assert m[b, u, u]
            if not valid[b, u]:
                continue
            p = parent[b, u]
            if p >= 0:
                # mask of u = mask of parent + self
                expect = m[b, p].copy()
                expect[u] = True
                assert (m[b, u] == expect).all()


def test_chain_tree_mask_is_causal():
    anchor = jnp.array([3, 4])
    toks = jnp.arange(10).reshape(2, 5)
    tree = T.chain_tree(anchor, toks)
    m = np.asarray(T.attention_mask(tree))
    tri = np.tril(np.ones((6, 6), bool))
    assert (m[0] == tri).all() and (m[1] == tri).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(3, 8), st.integers(1, 3), st.integers(0, 10 ** 6))
def test_propagate_and_best_path(g, k, seed):
    k = min(k, g - 1)
    tree, _ = make_comb(g, k, seed=seed)
    key = jax.random.PRNGKey(seed + 1)
    ok = jax.random.bernoulli(key, 0.6, (tree.b, tree.n))
    acc = np.asarray(T.propagate_acceptance(tree, ok))
    okn = np.asarray(ok)
    parent = np.asarray(tree.parent)
    valid = np.asarray(tree.valid)
    for b in range(tree.b):
        for n in range(tree.n):
            # brute-force ancestor check
            cur, good = n, True
            while cur != 0:
                if not okn[b, cur]:
                    good = False
                    break
                cur = parent[b, cur]
            assert acc[b, n] == good or n == 0

    best, n_acc, path = T.best_path(tree, jnp.asarray(acc))
    bestn, n_accn, pathn = map(np.asarray, (best, n_acc, path))
    depth = np.asarray(tree.depth)
    for b in range(tree.b):
        # n_acc is the max accepted depth
        cand = [depth[b, n] for n in range(tree.n)
                if acc[b, n] and valid[b, n]] + [0]
        assert n_accn[b] == max(cand)
        # path walks root -> best along parents
        assert pathn[b, 0] == 0
        for d in range(1, n_accn[b] + 1):
            assert parent[b, pathn[b, d]] == pathn[b, d - 1]
        assert depth[b, pathn[b, n_accn[b]]] == n_accn[b]


@settings(max_examples=15, deadline=None)
@given(st.integers(4, 8), st.integers(2, 3))
def test_children_table(g, k):
    tree, _ = make_comb(g, k, seed=g + 100 * k)
    tbl = np.asarray(T.children_table(tree, max_children=k + 1))
    parent = np.asarray(tree.parent)
    valid = np.asarray(tree.valid)
    for b in range(tree.b):
        for n in range(tree.n):
            kids = [c for c in tbl[b, n] if c >= 0]
            expect = [m for m in range(tree.n)
                      if valid[b, m] and parent[b, m] == n]
            assert kids == expect[: k + 1]
