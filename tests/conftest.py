import os
import sys

# Tests run on 1 CPU device (the dry-run sets its own XLA_FLAGS in a
# subprocess). Keep compilation light.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import pytest

from repro.config.base import ModelConfig
from repro.core.drafter import DrafterConfig, drafter_init
from repro.models import lm


def tiny_target(vocab=61, dtype="bfloat16", **kw):
    base = dict(num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
                d_ff=128, vocab_size=vocab, max_seq_len=256, remat=False,
                dtype=dtype)
    base.update(kw)
    return ModelConfig(**base)


def tiny_drafter(vocab=61, target_d=64, gamma=6, dtype="bfloat16",
                 target_cfg=None, **kw):
    if target_cfg is not None:
        fd = lm.feature_dim(target_cfg)
    else:
        fd = 3 * target_d
    base = dict(d_model=32, num_layers=2, num_heads=2, num_kv_heads=2,
                d_ff=64, vocab_size=vocab, target_feature_dim=fd,
                gamma=gamma, dtype=dtype)
    base.update(kw)
    return DrafterConfig(**base)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session", autouse=True)
def _repro_mesh():
    """Opt-in mesh context for the whole suite: REPRO_MESH="DxM" (e.g.
    "1x4") wraps every test in ``use_sharding`` over a (data, model) host
    mesh with the kv_seq axis on "model" — how ``scripts/tier1.sh --mesh``
    re-runs the tier-1 suite against the sharded engine. The caller must
    also export XLA_FLAGS=--xla_force_host_platform_device_count=N; no-op
    when REPRO_MESH is unset (the default 1-device run)."""
    spec = os.environ.get("REPRO_MESH")
    if not spec:
        yield
        return
    from repro.distributed.sharding import LOGICAL_RULES, use_sharding
    from repro.launch.mesh import make_mesh
    data, model = (int(x) for x in spec.lower().split("x"))
    mesh = make_mesh(data=data, model=model)
    with use_sharding(mesh, dict(LOGICAL_RULES, kv_seq="model")):
        yield


def pure_greedy(tp, tcfg, prompts, n):
    """Reference: cached greedy decoding, one token at a time."""
    b, p = prompts.shape
    states = lm.init_states(tcfg, b, p + n + 4,
                            dtype=jnp.dtype(tcfg.dtype))
    out = lm.forward(tp, prompts, tcfg, states=states, write_kv=True,
                     remat=False)
    states = out["states"]
    tok = jnp.argmax(out["logits"][:, -1], -1).astype(jnp.int32)
    res = [tok]
    for _ in range(n - 1):
        out = lm.forward(tp, tok[:, None], tcfg, states=states, write_kv=True,
                         attend_cache_on_write=True, remat=False)
        states = out["states"]
        tok = jnp.argmax(out["logits"][:, -1], -1).astype(jnp.int32)
        res.append(tok)
    return jnp.stack(res, 1)
