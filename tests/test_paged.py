"""Paged KV-cache subsystem: pool/page-table primitives, token parity of
``cache_impl="paged"`` against dense across the whole stack, page-granular
serving admission, and the copy-free slot-refill contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import SpecConfig
from repro.core import pipeline as pl
from repro.core.drafter import drafter_init
from repro.core.state import install_row, prefill_row, refill_copy_bytes
from repro.models import kvcache as kvc
from repro.models import lm
from repro.serving.engine import ServingEngine

from conftest import tiny_target, tiny_drafter, pure_greedy

GAMMA = 5
PAGE = 8


def _bundle(tcfg, gamma=GAMMA):
    dcfg = tiny_drafter(vocab=tcfg.vocab_size, gamma=gamma, dtype="float32",
                        target_cfg=tcfg)
    tp = lm.lm_init(jax.random.PRNGKey(0), tcfg)
    d1 = drafter_init(jax.random.PRNGKey(1), dcfg)
    d2 = drafter_init(jax.random.PRNGKey(2), dcfg)
    spec = SpecConfig(gamma=gamma, top_k_branches=2, mode="d2sd")
    return pl.SpecBundle(tcfg, dcfg, dcfg, spec, tp, d1, d2)


@pytest.fixture(scope="module")
def bundle():
    return _bundle(tiny_target(vocab=61, dtype="float32"))


# ------------------------------------------------------------- primitives --
def test_pool_scatter_view_roundtrip():
    """Random logical writes through the page table land exactly where a
    dense cache would put them (view == simulated dense buffer)."""
    rng = np.random.default_rng(0)
    b, mp, page, h, d = 3, 4, 8, 2, 4
    n_phys = 10
    perm = list(rng.permutation(n_phys))
    pt = np.full((b, mp), n_phys, np.int32)
    alloc = [4, 2, 3]                       # pages per row (ragged)
    for i, n in enumerate(alloc):
        pt[i, :n] = [perm.pop() for _ in range(n)]
    pool = jnp.zeros((n_phys, page, h, d), jnp.float32)
    dense = np.zeros((b, mp * page, h, d), np.float32)

    for start, t in ((0, 11), (11, 5), (16, 9)):
        new = rng.normal(size=(b, t, h, d)).astype(np.float32)
        pos = start + np.arange(t)[None, :] + np.zeros((b, 1), np.int32)
        valid = pos < (np.asarray(alloc) * page)[:, None]
        pool = kvc.pool_scatter(pool, jnp.asarray(pt), jnp.asarray(new),
                                jnp.asarray(pos))
        for i in range(b):
            for j in range(t):
                if valid[i, j]:
                    dense[i, pos[i, j]] = new[i, j]
    view = np.asarray(kvc.pool_view(pool, jnp.asarray(pt)))
    for i, n in enumerate(alloc):
        np.testing.assert_array_equal(view[i, : n * page],
                                      dense[i, : n * page])


def test_pool_scatter_stacked_layers():
    """[L, P, page, H, D] pools (feature caches / scanned periods) scatter
    per layer with one shared table."""
    l, b, mp, page, h, d = 2, 2, 2, 4, 1, 3
    pool = jnp.zeros((l, b * mp, page, h, d), jnp.float32)
    pt = kvc.identity_page_table(b, mp)
    new = jnp.arange(l * b * 3 * h * d, dtype=jnp.float32).reshape(
        l, b, 3, h, d)
    pos = jnp.asarray([[2, 3, 4], [0, 1, 2]])
    pool = kvc.pool_scatter(pool, pt, new, pos)
    view = np.asarray(kvc.pool_view(pool, pt))       # [L, B, mp*page, H, D]
    np.testing.assert_array_equal(view[:, 0, 2:5], np.asarray(new)[:, 0])
    np.testing.assert_array_equal(view[:, 1, 0:3], np.asarray(new)[:, 1])
    assert (view[:, 0, :2] == 0).all() and (view[:, 1, 3:] == 0).all()


def test_page_pool_alloc_free_invariants():
    pool = kvc.PagePool(6, PAGE)
    a = pool.alloc(4)
    assert len(set(a)) == 4 and pool.free_pages == 2
    assert pool.alloc(3) is None            # no partial grants
    b = pool.alloc(2)
    assert pool.free_pages == 0 and pool.peak_in_use == 6
    pool.free(a)
    assert pool.free_pages == 4 and pool.pages_in_use == 2
    c = pool.alloc(4)
    assert set(c) == set(a)                 # pages are recycled
    with pytest.raises(AssertionError):
        pool.free([c[0], c[0]])             # double free is a bug
    t = pool.row_table(b, max_pages=5)
    assert list(t[:2]) == b and (t[2:] == kvc.PAGE_SENTINEL).all()


# ----------------------------------------------------------- token parity --
def test_generate_paged_token_identity(bundle):
    """generate() with paged KV == dense == pure greedy, page-straddling
    prompt lengths included."""
    v = bundle.target_cfg.vocab_size
    prompts = jax.random.randint(jax.random.PRNGKey(3), (3, 9), 0, v)
    kd = jax.random.PRNGKey(7)
    dense = pl.generate(bundle, prompts, max_new=12, key=kd,
                        collect_stats=False)
    paged = pl.generate(bundle, prompts, max_new=12, key=kd,
                        collect_stats=False, cache_impl="paged",
                        page_size=PAGE)
    assert np.array_equal(dense["tokens"], paged["tokens"])
    ref = np.asarray(pure_greedy(bundle.target_params, bundle.target_cfg,
                                 prompts, 12))
    assert np.array_equal(np.asarray(paged["tokens"]), ref)
    assert dense["n_cycles"] == paged["n_cycles"]


def test_generate_ondevice_paged_token_identity(bundle):
    """The fully fused while_loop path works over paged states too."""
    v = bundle.target_cfg.vocab_size
    prompts = jax.random.randint(jax.random.PRNGKey(4), (2, 7), 0, v)
    kd = jax.random.PRNGKey(9)
    host = pl.generate(bundle, prompts, max_new=10, key=kd,
                       collect_stats=False, cache_impl="paged",
                       page_size=PAGE)
    dev = pl.generate_ondevice(bundle, prompts, max_new=10, key=kd,
                               cache_impl="paged", page_size=PAGE)
    assert np.array_equal(host["tokens"], np.asarray(dev["tokens"]))
    assert host["n_cycles"] == dev["n_cycles"]


def test_paged_local_global_hybrid_parity():
    """Sliding-window (local) layers keep dense rolling buffers while
    global layers page — the mix must stay token-exact."""
    tcfg = tiny_target(vocab=53, dtype="float32",
                       layer_pattern=("local", "global"), sliding_window=16)
    b = _bundle(tcfg, gamma=4)
    prompts = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0, 53)
    kd = jax.random.PRNGKey(9)
    dense = pl.generate(b, prompts, max_new=10, key=kd, collect_stats=False)
    paged = pl.generate(b, prompts, max_new=10, key=kd, collect_stats=False,
                        cache_impl="paged", page_size=PAGE)
    assert np.array_equal(dense["tokens"], paged["tokens"])
    ref = np.asarray(pure_greedy(b.target_params, tcfg, prompts, 10))
    assert np.array_equal(np.asarray(paged["tokens"]), ref)


def test_paged_hybrid_recurrent_global_parity():
    """Hybrid recurrent+global target: the state-replay verifier's branch
    fold must replicate page-table rows but NOT the (batch-free) pools,
    and the snap_at replay writes page-wise."""
    tcfg = tiny_target(vocab=47, dtype="float32",
                       layer_pattern=("recurrent", "global"))
    b = _bundle(tcfg, gamma=4)
    from repro.core.verify import select_backend
    assert select_backend(tcfg).name == "state_replay"
    prompts = jax.random.randint(jax.random.PRNGKey(3), (2, 7), 0, 47)
    kd = jax.random.PRNGKey(5)
    dense = pl.generate(b, prompts, max_new=8, key=kd, collect_stats=False)
    paged = pl.generate(b, prompts, max_new=8, key=kd, collect_stats=False,
                        cache_impl="paged", page_size=PAGE)
    assert np.array_equal(dense["tokens"], paged["tokens"])
    ref = np.asarray(pure_greedy(b.target_params, tcfg, prompts, 8))
    assert np.array_equal(np.asarray(paged["tokens"]), ref)


def test_paged_state_replay_backend_parity():
    """Attention-free target (rwkv): the state-replay verifier runs with
    paged feature caches (the only paged leaves) — parity must hold."""
    tcfg = tiny_target(vocab=43, dtype="float32", layer_pattern=("rwkv",),
                       rwkv_head_dim=16)
    b = _bundle(tcfg, gamma=4)
    prompts = jax.random.randint(jax.random.PRNGKey(5), (2, 6), 0, 43)
    kd = jax.random.PRNGKey(11)
    dense = pl.generate(b, prompts, max_new=8, key=kd, collect_stats=False)
    paged = pl.generate(b, prompts, max_new=8, key=kd, collect_stats=False,
                        cache_impl="paged", page_size=4)
    assert np.array_equal(dense["tokens"], paged["tokens"])


# ------------------------------------------------------ install / refill ---
def test_paged_prefill_row_isolated(bundle):
    """Paged slot install: adopted row prefills into its own pages; every
    other row's logical view, length, and anchor are bit-identical."""
    v = bundle.target_cfg.vocab_size
    prompts = jax.random.randint(jax.random.PRNGKey(3), (3, 8), 0, v)
    state = pl.engine_init(bundle, 3, 64, cache_impl="paged", page_size=PAGE)
    state = pl.prefill(bundle, state, prompts)
    newp = jax.random.randint(jax.random.PRNGKey(8), (12,), 0, v)
    st2 = prefill_row(bundle, state, 1, newp, key=jax.random.PRNGKey(11))
    assert int(st2.length[1]) == 12
    assert [int(st2.length[i]) for i in (0, 2)] == \
        [int(state.length[i]) for i in (0, 2)]
    # neighbors' logical feature-cache views untouched
    old = np.asarray(kvc.pool_view(state.d1_feat["k"], state.d1_feat["pt"]))
    new = np.asarray(kvc.pool_view(st2.d1_feat["k"], st2.d1_feat["pt"]))
    np.testing.assert_array_equal(new[:, 0], old[:, 0])
    np.testing.assert_array_equal(new[:, 2], old[:, 2])
    assert not np.array_equal(new[:, 1], old[:, 1])
    # the adopted row's anchor equals a standalone prefill's first token
    ref = np.asarray(pure_greedy(bundle.target_params, bundle.target_cfg,
                                 jnp.asarray(newp)[None], 1))[0]
    assert int(st2.anchor[1]) == int(ref[0])


def test_install_row_donated_matches_prefill_row(bundle):
    """The serving fast path (donated jit install) and the non-donating
    prefill_row agree on the resulting state: integer leaves (tokens,
    lengths, page tables) exactly, float caches to jit-vs-eager rounding."""
    v = bundle.target_cfg.vocab_size
    prompts = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0, v)
    newp = jax.random.randint(jax.random.PRNGKey(8), (10,), 0, v)
    mk = lambda: pl.prefill(bundle, pl.engine_init(       # noqa: E731
        bundle, 2, 48, cache_impl="paged", page_size=PAGE), prompts)
    mp = mk().max_pages
    a = prefill_row(bundle, mk(), 1, newp, key=jax.random.PRNGKey(2))
    b = install_row(bundle, mk(), 1, newp, key=jax.random.PRNGKey(2),
                    row_table=mp + jnp.arange(mp, dtype=jnp.int32))
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        la, lb = np.asarray(la), np.asarray(lb)
        if np.issubdtype(la.dtype, np.integer) or la.dtype == bool:
            np.testing.assert_array_equal(la, lb)
        else:
            np.testing.assert_allclose(la, lb, rtol=1e-5, atol=1e-6)


def test_refill_copy_bytes_page_order(bundle):
    """The install accounting model: paged installs cost page-order bytes,
    dense installs cost a full max_len row."""
    prompts = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0, 61)
    dense = pl.prefill(bundle, pl.engine_init(bundle, 2, 256), prompts)
    paged = pl.prefill(bundle, pl.engine_init(
        bundle, 2, 256, cache_impl="paged", page_size=PAGE), prompts)
    bd = refill_copy_bytes(dense, 8)
    bp = refill_copy_bytes(paged, 8)
    assert bp * 8 < bd        # page-order, not max_len-order
    # dense scales with capacity, paged with the prompt
    dense_big = pl.engine_init(bundle, 2, 512)
    assert refill_copy_bytes(dense_big, 8) > 1.8 * bd
    paged_big = pl.engine_init(bundle, 2, 512, cache_impl="paged",
                               page_size=PAGE)
    assert refill_copy_bytes(paged_big, 8) == pytest.approx(bp, rel=0.05)


def test_decode_cycle_paged_inactive_row_frozen(bundle):
    """A masked row of a paged wave freezes its page table AND its pages'
    contents through a decode cycle."""
    v = bundle.target_cfg.vocab_size
    prompts = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0, v)
    state = pl.engine_init(bundle, 2, 64, cache_impl="paged", page_size=PAGE)
    state = pl.prefill(bundle, state, prompts)
    state = state.replace(active=jnp.asarray([True, False]))
    state2, out = pl.decode_cycle(bundle, state, jax.random.PRNGKey(1),
                                  collect_stats=False)
    n_out = np.asarray(out["n_out"])
    assert n_out[0] >= 1 and n_out[1] == 0
    assert int(state2.length[1]) == int(state.length[1])
    assert int(state2.length[0]) > int(state.length[0])
    # page table frozen for both rows (allocation is install-time only)...
    np.testing.assert_array_equal(np.asarray(state2.d1_feat["pt"]),
                                  np.asarray(state.d1_feat["pt"]))
    # ...and the inactive row's logical view is bit-identical
    old = np.asarray(kvc.pool_view(state.d1_feat["k"], state.d1_feat["pt"]))
    new = np.asarray(kvc.pool_view(state2.d1_feat["k"],
                                   state2.d1_feat["pt"]))
    np.testing.assert_array_equal(new[:, 1], old[:, 1])
    assert not np.array_equal(new[:, 0], old[:, 0])


# ---------------------------------------------------------------- serving --
def _traffic(v, seed=0):
    rng = np.random.default_rng(seed)
    plens = (8, 11, 8, 9, 10)
    wants = (6, 14, 9, 5, 11)
    return [rng.integers(0, v, size=p).astype(np.int32) for p in plens], wants


def _serve(bundle, prompts, wants, **kw):
    eng = ServingEngine(bundle, batch_size=2, **kw)
    for p, n in zip(prompts, wants):
        eng.submit(p, max_new=n)
    stats = eng.run()
    return eng, stats


def test_serving_paged_token_parity_and_page_accounting(bundle):
    """Same traffic through dense and paged engines: identical per-request
    tokens; paged refills allocate/free pages and report page-order
    refill-copy bytes (the PR acceptance criterion)."""
    prompts, wants = _traffic(bundle.target_cfg.vocab_size)
    ed, sd = _serve(bundle, prompts, wants, cache_impl="dense")
    ep, sp = _serve(bundle, prompts, wants, cache_impl="paged",
                    page_size=PAGE)
    outs = lambda e: {r.uid: r.out.tolist() for r in e.done}  # noqa: E731
    assert outs(ed) == outs(ep)
    assert sp["refills"] == sd["refills"] and sp["refills"] > 0
    assert sp["pool_pages"] > 0
    assert 0 < sp["pool_peak_pages"] <= sp["pool_pages"]
    assert 0.0 < sp["pool_utilization"] <= 1.0
    # copy-free refill: paged installs write page-order bytes, a small
    # fraction of the dense row splice
    assert sp["installs"] == sd["installs"]
    assert sp["refill_copy_bytes"] * 3 < sd["refill_copy_bytes"]
    # every request checks out against standalone greedy decoding
    for r in ep.done:
        ref = np.asarray(pure_greedy(
            bundle.target_params, bundle.target_cfg,
            jnp.asarray(prompts[r.uid])[None], r.max_new))[0]
        assert np.array_equal(r.out, ref), r.uid


def test_serving_paged_requires_early_exit(bundle):
    """Legacy all-rows-run mode would let retired slots write through
    stale page tables into freed pages — the engine must refuse it."""
    with pytest.raises(ValueError, match="early_exit"):
        ServingEngine(bundle, cache_impl="paged", early_exit=False)


def test_serving_paged_prefill_burst_pool_pressure(bundle):
    """Regression: max_new<=1 bursts retire during start_wave and
    chain-refill from beyond the pool-sizing candidate window; the initial
    installs must still get their guaranteed pages (install-all before
    retire-any), and every request must complete correctly."""
    v = bundle.target_cfg.vocab_size
    rng = np.random.default_rng(7)
    mk = lambda n: rng.integers(0, v, size=n).astype(np.int32)  # noqa: E731
    # slot 0's burst drains several queue entries (incl. a page-hungry one)
    # before slot 1's big initial request is installed
    reqs = [(mk(6), 1), (mk(10), 12), (mk(6), 1), (mk(6), 1), (mk(12), 10),
            (mk(6), 4)]
    eng = ServingEngine(bundle, batch_size=2, cache_impl="paged",
                        page_size=PAGE)
    for p, n in reqs:
        eng.submit(p, max_new=n)
    stats = eng.run()
    assert len(eng.done) == len(reqs)
    for r in eng.done:
        ref = np.asarray(pure_greedy(
            bundle.target_params, bundle.target_cfg,
            jnp.asarray(reqs[r.uid][0])[None], r.max_new))[0]
        assert np.array_equal(r.out, ref), r.uid
    assert stats["pool_peak_pages"] <= stats["pool_pages"]


def test_serving_paged_pool_reuse_across_retires(bundle):
    """Sustained traffic through a small batch recycles freed pages: the
    pool peak stays at the worst-case concurrent set, not the total
    traffic volume."""
    v = bundle.target_cfg.vocab_size
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, v, size=8).astype(np.int32)
               for _ in range(6)]
    wants = [4] * 6
    ep, sp = _serve(bundle, prompts, wants, cache_impl="paged",
                    page_size=PAGE)
    assert len(ep.done) == 6 and sp["waves"] == 1
    need = -(-(8 + 4 + 2 * GAMMA + 8) // PAGE)        # pages per request
    assert sp["pool_peak_pages"] <= 2 * need          # batch_size concurrent
    for r in ep.done:
        ref = np.asarray(pure_greedy(
            bundle.target_params, bundle.target_cfg,
            jnp.asarray(prompts[r.uid])[None], r.max_new))[0]
        assert np.array_equal(r.out, ref), r.uid
