"""Minimal stand-in for ``hypothesis`` when the real package is absent.

The container image does not ship hypothesis and tier-1 cannot install
packages, so property tests fall back to deterministic random example
sampling: ``@given`` draws ``max_examples`` tuples from a fixed-seed RNG
and runs the test body once per tuple. Shrinking, assume(), and stateful
testing are not supported — only the subset this repo uses
(integers/floats/booleans/lists, @settings(max_examples, deadline)).
"""
from __future__ import annotations


import random


class _Strategy:
    def __init__(self, sample):
        self.sample = sample


class strategies:  # noqa: N801 - mimics the hypothesis.strategies module
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(lambda r: r.uniform(min_value, max_value))

    @staticmethod
    def booleans():
        return _Strategy(lambda r: r.random() < 0.5)

    @staticmethod
    def lists(elements, min_size=0, max_size=10):
        return _Strategy(
            lambda r: [elements.sample(r)
                       for _ in range(r.randint(min_size, max_size))])


def settings(max_examples: int = 20, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(*strats):
    def deco(fn):
        # No functools.wraps: pytest must see a zero-arg signature, not the
        # original one (it would mistake generated args for fixtures).
        def runner():
            n = getattr(runner, "_max_examples", 20)
            rnd = random.Random(0xD25D)
            for _ in range(n):
                fn(*(s.sample(rnd) for s in strats))
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        return runner
    return deco
