"""Strategy/backend engine API: registry dispatch, typed state, on-device
generation, and the serving step() wave protocol."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import KNOWN_STRATEGIES, SpecConfig
from repro.core import pipeline as pl
from repro.core import strategies as strat_lib
from repro.core import verify as verify_lib
from repro.core.drafter import drafter_init
from repro.core.state import EngineState
from repro.models import lm
from repro.serving.engine import ServingEngine

from conftest import tiny_target, tiny_drafter, pure_greedy

GAMMA = 6


def _bundle(mode="d2sd", temperature=0.0, third=False, vocab=61):
    tcfg = tiny_target(vocab=vocab, dtype="float32")
    dcfg = tiny_drafter(vocab=vocab, gamma=GAMMA, dtype="float32",
                        causal=(mode == "eagle"), target_cfg=tcfg)
    tp = lm.lm_init(jax.random.PRNGKey(0), tcfg)
    d1 = drafter_init(jax.random.PRNGKey(1), dcfg)
    d2 = drafter_init(jax.random.PRNGKey(2), dcfg)
    spec = SpecConfig(gamma=GAMMA, top_k_branches=2, mode=mode,
                      temperature=temperature, third_level=third)
    return pl.SpecBundle(tcfg, dcfg, dcfg, spec, tp,
                         d1, d1 if mode == "dflash_second" else d2)


# --------------------------------------------------------------- registry --
def test_registry_has_all_paper_modes():
    reg = strat_lib.registered_strategies()
    assert set(KNOWN_STRATEGIES) <= set(reg)
    for name in KNOWN_STRATEGIES:
        s = strat_lib.get_strategy(name)
        assert s.name == name
        assert s.n_draft_passes(SpecConfig(mode=name)) >= 1
        assert s.n_tree_nodes(SpecConfig(mode=name)) >= 2


def test_unknown_strategy_raises():
    with pytest.raises(KeyError, match="registered"):
        strat_lib.get_strategy("nope")
    with pytest.raises(ValueError, match="registered draft strategy"):
        SpecConfig(mode="nope")


@pytest.mark.parametrize("mode", list(KNOWN_STRATEGIES))
def test_alias_registration_is_token_identical(mode):
    """Dispatch is purely registry-driven: the same strategy class
    re-registered under an alias emits token-identical output to the
    original mode string on a fixed seed."""
    alias = f"alias_{mode}"
    cls = strat_lib.registered_strategies()[mode]
    try:
        strat_lib.register_strategy(alias)(cls)
        bundle = _bundle(mode)
        prompts = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0,
                                     bundle.target_cfg.vocab_size)
        ref = pl.generate(bundle, prompts, max_new=12,
                          key=jax.random.PRNGKey(7))
        spec2 = dataclasses.replace(bundle.spec, mode=alias)
        bundle2 = dataclasses.replace(bundle, spec=spec2)
        out = pl.generate(bundle2, prompts, max_new=12,
                          key=jax.random.PRNGKey(7))
        assert np.array_equal(out["tokens"], ref["tokens"]), mode
    finally:
        # restore original class name and drop the alias entry
        strat_lib._REGISTRY.pop(alias, None)
        cls.name = mode


def test_plugin_strategy_dispatches():
    """A user-registered strategy is reachable through decode_cycle with no
    engine change (the one-file-plugin contract)."""
    from repro.core import tree as tree_lib

    @strat_lib.register_strategy("anchor_echo")
    class AnchorEcho(strat_lib.DraftStrategy):
        """Drafts a 1-token chain that just repeats the anchor."""

        def draft(self, bundle, state, key):
            tree = tree_lib.chain_tree(state.anchor, state.anchor[:, None])
            return strat_lib.DraftResult(tree=tree, dprobs=None, conf=None,
                                         max_children=1)

        def n_draft_passes(self, spec):
            return 0

        def n_tree_nodes(self, spec):
            return 2

    try:
        bundle = _bundle("d2sd")
        spec = dataclasses.replace(bundle.spec, mode="anchor_echo")
        bundle = dataclasses.replace(bundle, spec=spec)
        prompts = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0,
                                     bundle.target_cfg.vocab_size)
        ref = np.asarray(pure_greedy(bundle.target_params, bundle.target_cfg,
                                     prompts, 8))
        out = pl.generate(bundle, prompts, max_new=8,
                          key=jax.random.PRNGKey(7))
        # a useless drafter still yields exact greedy output (verify rule)
        assert np.array_equal(out["tokens"], ref)
    finally:
        strat_lib._REGISTRY.pop("anchor_echo", None)


# ------------------------------------------------------- backends / state --
def test_backend_selection_by_capability():
    attn = tiny_target(dtype="float32")
    ssm = tiny_target(dtype="float32", layer_pattern=("rwkv",),
                      rwkv_head_dim=16)
    assert isinstance(verify_lib.select_backend(attn),
                      verify_lib.TreeAttentionVerifier)
    assert isinstance(verify_lib.select_backend(ssm),
                      verify_lib.StateReplayVerifier)


def test_engine_state_is_pytree():
    bundle = _bundle("d2sd")
    state = pl.engine_init(bundle, 2, 32)
    leaves, treedef = jax.tree_util.tree_flatten(state)
    state2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(state2, EngineState)
    assert state2.batch == 2
    assert state2.length.shape == (2,)


# ------------------------------------------------------- ondevice loop -----
@pytest.mark.parametrize("mode", ["d2sd", "dflash"])
def test_generate_ondevice_matches_host_loop(mode):
    bundle = _bundle(mode)
    prompts = jax.random.randint(jax.random.PRNGKey(3), (3, 8), 0,
                                 bundle.target_cfg.vocab_size)
    host = pl.generate(bundle, prompts, max_new=16,
                       key=jax.random.PRNGKey(7), collect_stats=False)
    dev = pl.generate_ondevice(bundle, prompts, max_new=16,
                               key=jax.random.PRNGKey(7))
    assert np.array_equal(host["tokens"], np.asarray(dev["tokens"])), mode
    assert host["n_cycles"] == dev["n_cycles"]
    assert abs(host["alpha"] - dev["alpha"]) < 1e-9


def test_generate_ondevice_is_greedy_exact():
    bundle = _bundle("d2sd")
    prompts = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0,
                                 bundle.target_cfg.vocab_size)
    ref = np.asarray(pure_greedy(bundle.target_params, bundle.target_cfg,
                                 prompts, 12))
    out = pl.generate_ondevice(bundle, prompts, max_new=12,
                               key=jax.random.PRNGKey(7))
    assert np.array_equal(np.asarray(out["tokens"]), ref)


# ------------------------------------------------------------- serving -----
def test_submit_uids_stay_unique_across_drained_waves():
    bundle = _bundle("dflash")
    eng = ServingEngine(bundle, batch_size=2)
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(3), (2, 8), 0, bundle.target_cfg.vocab_size))
    first = [eng.submit(p, max_new=4) for p in prompts]
    eng.run()                       # drains the queue into done
    second = [eng.submit(p, max_new=4) for p in prompts]
    eng.run()
    uids = first + second
    assert len(set(uids)) == len(uids), uids
    assert sorted(r.uid for r in eng.done) == sorted(uids)


def test_wave_step_mixes_max_new_without_reprefill():
    bundle = _bundle("d2sd")
    v = bundle.target_cfg.vocab_size
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(3), (3, 8), 0, v))
    ref = np.asarray(pure_greedy(bundle.target_params, bundle.target_cfg,
                                 jnp.asarray(prompts), 18))
    eng = ServingEngine(bundle, batch_size=4)
    wants = [6, 12, 18]
    for p, n in zip(prompts, wants):
        eng.submit(p, max_new=n)
    stats = eng.run()
    assert stats["waves"] == 1      # one prefill served all three budgets
    assert len(eng.done) == 3
    by_uid = sorted(eng.done, key=lambda r: r.uid)
    for i, (r, n) in enumerate(zip(by_uid, wants)):
        assert r.out.shape == (n,)
        # greedy decode is key-independent: engine == pure target greedy
        assert np.array_equal(r.out, ref[i, :n]), i
