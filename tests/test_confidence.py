"""Unit + property tests for the rejection-boundary estimator (Eqs. 3-5)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # container lacks hypothesis; see _hypo_shim
    from _hypo_shim import given, settings, strategies as st

from repro.core import confidence as C


def test_confidence_is_max_prob():
    logits = jnp.array([[[0.0, 2.0, 1.0], [3.0, 0.0, 0.0]]])
    c = C.confidences(logits)
    p = jax.nn.softmax(logits, -1).max(-1)
    np.testing.assert_allclose(np.asarray(c), np.asarray(p), rtol=1e-6)


def test_confidence_of_chosen_token():
    logits = jnp.array([[[0.0, 2.0, 1.0]]])
    tok = jnp.array([[2]])
    c = C.confidences(logits, tok)
    p = jax.nn.softmax(logits, -1)[0, 0, 2]
    np.testing.assert_allclose(float(c[0, 0]), float(p), rtol=1e-6)


def test_boundary_posterior_example():
    # Eq. 4 hand check: conf = [.9, .5]:
    # r(0) = (1-.9) = .1 ; r(1) = .9*(1-.5) = .45
    conf = jnp.array([[0.9, 0.5]])
    r = C.boundary_posterior(conf)
    np.testing.assert_allclose(np.asarray(r[0]), [0.1, 0.45], rtol=1e-6)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(0.01, 0.99), min_size=2, max_size=12))
def test_posterior_is_subdistribution(confs):
    """sum_i r(i) = 1 - prod(c) (leftover = all-accepted event)."""
    conf = jnp.array([confs])
    r = np.asarray(C.boundary_posterior(conf))[0]
    assert (r >= -1e-6).all()
    total = r.sum()
    expect = 1.0 - np.prod(confs)
    np.testing.assert_allclose(total, expect, rtol=1e-4)


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 10), st.integers(1, 4))
def test_topk_selects_highest(g, k):
    key = jax.random.PRNGKey(g * 13 + k)
    conf = jax.random.uniform(key, (2, g), minval=0.05, maxval=0.95)
    r = C.boundary_posterior(conf)
    k = min(k, g)
    scores, idx = C.topk_prefixes(r, k)
    rn = np.asarray(r)
    for b in range(2):
        top = np.sort(rn[b])[::-1][:k]
        np.testing.assert_allclose(np.sort(np.asarray(scores[b]))[::-1], top,
                                   rtol=1e-6)
        assert len(set(np.asarray(idx[b]).tolist())) == k  # distinct forks
