"""Sharded resident serving: ONE engine spanning a host mesh.

Subprocess tests (the main pytest process keeps 1 CPU device; these
spawn ``python -c`` under ``XLA_FLAGS=--xla_force_host_platform_
device_count=8``) asserting the tentpole invariant — *page identity is
global, page bytes are per-shard* — end to end:

* a mixed serving workload (varying prompt lengths and budgets, radix
  prefix cache on, cross-wave hits through the engine-lifetime pool) is
  per-request TOKEN-IDENTICAL between a ``kv_seq``-sharded engine and
  the single-device engine, with both engines living in ONE process
  (exercising the ``mesh_tag`` static jit-cache split);
* the seed-0 chunk of the randomized pool/radix/COW invariant suite
  passes unchanged against the per-shard pool (``REPRO_MESH`` re-runs
  it inside a ``use_sharding`` context — the host allocator, refcounts
  and radix tree never see the mesh, so every invariant must hold
  verbatim).
"""
import os
import subprocess
import sys
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")
TESTS = str(Path(__file__).resolve().parent)


def _run(code: str, devices: int = 8, extra_env=None) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + TESTS
    env.pop("JAX_PLATFORMS", None)
    env.pop("REPRO_MESH", None)
    if extra_env:
        env.update(extra_env)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    return out.stdout


def test_sharded_engine_token_parity_and_cross_wave_hits():
    """Sharded-vs-single-device ServingEngine parity on a mixed resident
    workload: two submission phases with a wave turnover between them,
    phase-2 prompts extending phase-1 strings so the radix hits cross the
    turnover THROUGH the kv_seq-sharded engine pool."""
    _run(r"""
import contextlib
import numpy as np, jax
from conftest import tiny_target, tiny_drafter
from repro.config.base import SpecConfig
from repro.core import pipeline as pl
from repro.core.drafter import drafter_init
from repro.distributed import sharding as sh
from repro.launch.mesh import make_mesh
from repro.models import lm
from repro.serving.engine import ServingEngine

assert jax.device_count() == 8, jax.device_count()
VOCAB, GAMMA = 61, 4
tcfg = tiny_target(vocab=VOCAB, dtype="float32")
dcfg = tiny_drafter(vocab=VOCAB, gamma=GAMMA, dtype="float32",
                    target_cfg=tcfg)
tp = lm.lm_init(jax.random.PRNGKey(0), tcfg)
d1 = drafter_init(jax.random.PRNGKey(1), dcfg)
d2 = drafter_init(jax.random.PRNGKey(2), dcfg)
spec = SpecConfig(gamma=GAMMA, top_k_branches=2, mode="d2sd")
bundle = pl.SpecBundle(tcfg, dcfg, dcfg, spec, tp, d1, d2)

rng = np.random.default_rng(0)
sysp = rng.integers(3, VOCAB, size=11).astype(np.int32)
phase1 = []
for i in range(4):
    tail = rng.integers(3, VOCAB, size=3 + 2 * i).astype(np.int32)
    phase1.append((np.concatenate([sysp, tail]), 3 + (i % 3)))
# phase 2 re-sends phase-1 prompts (hits must cover prompt + committed
# tokens) plus fresh mixed-length cold prompts
phase2 = [(p, n) for p, n in phase1[:2]]
for i in range(2):
    phase2.append((rng.integers(3, VOCAB, size=6 + 5 * i).astype(np.int32),
                   4))

def serve(mesh):
    ctx = (sh.use_sharding(mesh, dict(sh.LOGICAL_RULES, kv_seq="model"))
           if mesh is not None else contextlib.nullcontext())
    with ctx:
        eng = ServingEngine(bundle, batch_size=2, seed=0,
                            cache_impl="paged", page_size=8,
                            prefix_cache=True, pool_scope="engine")
    for p, n in phase1:
        eng.submit(p, max_new=n)
    eng.run()                       # wave(s) 1: seeds the radix tree
    hits0 = eng.stats["prefix_hit_tokens"]
    waves0 = eng.stats["waves"]
    for p, n in phase2:
        eng.submit(p, max_new=n)
    stats = eng.run()               # new wave over the SAME engine pool
    assert stats["waves"] > waves0
    outs = {r.uid: r.out.tolist() for r in eng.done}
    return outs, stats, stats["prefix_hit_tokens"] - hits0

o_ref, s_ref, _ = serve(None)
o_sh, s_sh, hits_across = serve(make_mesh(data=2, model=4))
assert o_sh == o_ref, {u: (o_sh.get(u), o_ref.get(u)) for u in o_ref
                       if o_sh.get(u) != o_ref[u]}
assert s_sh["kv_shards"] == 4, s_sh["kv_shards"]
assert s_sh["pool_shard_slots"] * 4 == s_sh["pool_pages"] * 8, s_sh
# radix hits crossed the wave turnover through the sharded pool
assert hits_across > 0, s_sh
assert s_sh["decode_collective_bytes"] > 0, s_sh
# single-device engine in the same process stayed mesh-free
assert s_ref["kv_shards"] == 1 and s_ref["decode_collective_bytes"] == 0
print("parity ok")
""")


def test_sharded_pallas_read_path_token_parity():
    """attn_impl="pallas" vs "gather" on the kv_seq-sharded paged engine:
    the cascade kernel runs on each shard's local pool slice inside
    shard_map (pos_stride = global page, pos_offset = shard * page_loc,
    LSE psum merge across shards) and per-request tokens must be
    identical to the gather read path on the same mesh."""
    _run(r"""
import numpy as np, jax
from conftest import tiny_target, tiny_drafter
from repro.config.base import SpecConfig
from repro.core import pipeline as pl
from repro.core.drafter import drafter_init
from repro.distributed import sharding as sh
from repro.launch.mesh import make_mesh
from repro.models import lm
from repro.serving.engine import ServingEngine

assert jax.device_count() == 8, jax.device_count()
VOCAB, GAMMA = 61, 4
tcfg = tiny_target(vocab=VOCAB, dtype="float32")
dcfg = tiny_drafter(vocab=VOCAB, gamma=GAMMA, dtype="float32",
                    target_cfg=tcfg)
tp = lm.lm_init(jax.random.PRNGKey(0), tcfg)
d1 = drafter_init(jax.random.PRNGKey(1), dcfg)
d2 = drafter_init(jax.random.PRNGKey(2), dcfg)
spec = SpecConfig(gamma=GAMMA, top_k_branches=2, mode="d2sd")
bundle = pl.SpecBundle(tcfg, dcfg, dcfg, spec, tp, d1, d2)

rng = np.random.default_rng(1)
reqs = [(rng.integers(3, VOCAB, size=p).astype(np.int32), n)
        for p, n in [(11, 4), (5, 3), (8, 5), (6, 3)]]

mesh = make_mesh(data=2, model=4)
outs = {}
for impl in ("gather", "pallas"):
    with sh.use_sharding(mesh, dict(sh.LOGICAL_RULES, kv_seq="model")):
        eng = ServingEngine(pl.with_attn_impl(bundle, impl),
                            batch_size=2, seed=0, cache_impl="paged",
                            page_size=8)
    for p, n in reqs:
        eng.submit(p, max_new=n)
    stats = eng.run()
    assert stats["kv_shards"] == 4, stats["kv_shards"]
    outs[impl] = {r.uid: r.out.tolist() for r in eng.done}
assert outs["pallas"] == outs["gather"], {
    u: (outs["pallas"].get(u), outs["gather"].get(u))
    for u in outs["gather"] if outs["pallas"].get(u) != outs["gather"][u]}
print("sharded pallas parity ok")
""")


def test_sharded_drafter_read_parity():
    """Drafter feature-cache reads under a kv_seq mesh go through the
    SAME shard_map hook as the verify read (ROADMAP item d closed): a
    paged ``drafter_forward`` on a 4-way kv_seq mesh must produce logits
    identical to the meshless gather path, for both read_impls, and the
    shard_map hook must actually engage (its LSE-psum payload shows up
    in PAYLOAD_TRACE)."""
    _run(r"""
import numpy as np, jax
import jax.numpy as jnp
from conftest import tiny_target, tiny_drafter
from repro.core import drafter as dr
from repro.distributed import sharding as sh
from repro.distributed import spdecode as sp
from repro.launch.mesh import make_mesh

assert jax.device_count() == 8, jax.device_count()
VOCAB, GAMMA = 61, 4
tcfg = tiny_target(vocab=VOCAB, dtype="float32")
dcfg = tiny_drafter(vocab=VOCAB, gamma=GAMMA, dtype="float32",
                    target_cfg=tcfg)
p = dr.drafter_init(jax.random.PRNGKey(1), dcfg)

B, PAGE, MP = 2, 8, 6
cache = dr.init_feat_cache(dcfg, B, PAGE * MP, dtype=jnp.float32,
                           cache_impl="paged", page_size=PAGE)
rng = np.random.default_rng(0)
feats = jnp.asarray(rng.standard_normal(
    (B, 13, dcfg.target_feature_dim)), jnp.float32)
pos = jnp.broadcast_to(jnp.arange(13)[None], (B, 13))
n_new = jnp.array([13, 9])                  # ragged, page-straddling
cache = dr.extend_feat_cache(p, dcfg, cache, feats, pos, n_new)
blk = dr.dflash_block(jnp.array([5, 7]), GAMMA, dcfg.mask_token)

ref = np.asarray(dr.drafter_forward(p, dcfg, blk, cache))
mesh = make_mesh(data=2, model=4)
for impl in ("gather", "pallas"):
    dci = __import__("dataclasses").replace(dcfg, attn_impl=impl)
    with sh.use_sharding(mesh, dict(sh.LOGICAL_RULES, kv_seq="model")):
        sp.PAYLOAD_TRACE.clear()
        out = np.asarray(dr.drafter_forward(p, dci, blk, cache))
        assert len(sp.PAYLOAD_TRACE) == dcfg.num_layers, (
            impl, len(sp.PAYLOAD_TRACE))   # shard_map hook engaged
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)
print("sharded drafter read parity ok")
""")


def test_pool_invariants_seed0_under_mesh():
    """The tier-1 (seed-0) chunk of the pool/radix/COW invariant suite,
    re-run with every test wrapped in a 1x4 kv_seq mesh context via the
    REPRO_MESH conftest fixture: page identity is host-global, so the
    refcount / free-list / COW bit-freeze invariants must hold verbatim
    over the per-shard pool."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    env["REPRO_MESH"] = "1x4"
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", "-m", "not slow",
         "-p", "no:cacheprovider",
         str(Path(TESTS) / "test_pool_invariants.py"),
         "-k", "randomized_pool_invariants or cached_pages_survive"],
        env=env, capture_output=True, text=True, timeout=900,
        cwd=str(Path(TESTS).parent))
    assert out.returncode == 0, (out.stdout[-3000:], out.stderr[-2000:])
