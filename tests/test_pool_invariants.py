"""Randomized invariant suite for the pool / radix / COW stack.

Drives scripted multi-wave serving schedules (random prompts sharing
prefix families, random budgets, bursty arrivals so waves really turn
over) through the engine-lifetime page pool and asserts, after EVERY
engine step, the global invariants of the refcounted COW page machinery:

* refcounts equal the table + radix-tree reference counts, reconstructed
  host-side from the live rows' page lists and the tree's node pages;
* the free list and the referenced set are disjoint;
* no page appears in two rows' page tables unless its refcount covers
  every reader;
* a page with refcount > 1 is never written — enforced behaviorally by
  :class:`SharedPageWriteMonitor`, which snapshots every shared page's
  device contents and requires bit-identity for as long as the page stays
  shared (true write logging is impossible from the host: commits run
  inside jitted decode cycles, so bit-freezing IS the observable
  contract).

Also here: cross-wave token parity (legacy per-wave pools vs the
engine-lifetime pool cache-off/cache-on, plus ``generate_ondevice``
parity), cached-page survival across wave turnover, LRU eviction under
multi-wave churn, and the engine-global pool-sizing regression (the old
prefix-cache rule double-counted likely-refill candidates).

Tier-1 runs the seed-0 schedule; ``scripts/tier1.sh --stress`` adds the
reroll seeds (marked ``slow``).
"""
import collections

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import SpecConfig
from repro.core import pipeline as pl
from repro.core.drafter import drafter_init
from repro.core.state import capture_pools
from repro.models import lm
from repro.serving.engine import Request, ServingEngine

from conftest import tiny_target, tiny_drafter, pure_greedy

GAMMA = 4
PAGE = 8


@pytest.fixture(scope="module")
def bundle():
    tcfg = tiny_target(vocab=61, dtype="float32")
    dcfg = tiny_drafter(vocab=61, gamma=GAMMA, dtype="float32",
                        target_cfg=tcfg)
    tp = lm.lm_init(jax.random.PRNGKey(0), tcfg)
    d1 = drafter_init(jax.random.PRNGKey(1), dcfg)
    d2 = drafter_init(jax.random.PRNGKey(2), dcfg)
    spec = SpecConfig(gamma=GAMMA, top_k_branches=2, mode="d2sd")
    return pl.SpecBundle(tcfg, dcfg, dcfg, spec, tp, d1, d2)


def _ref(bundle, prompt, n):
    return np.asarray(pure_greedy(bundle.target_params, bundle.target_cfg,
                                  jnp.asarray(prompt)[None], n))[0]


# ===================================================== invariant checker ===
def _live_refs(eng):
    """(pool, cache, refs, tables): host-side reconstruction of every page
    reference — tree node pages plus, per live row, its private pages and
    its hit's shared pages — and the per-row table page sets."""
    w = eng.wave
    pool = eng.pool if eng.pool is not None else (w.pool if w else None)
    cache = eng.cache if eng.cache is not None else (w.cache if w else None)
    refs = collections.Counter()
    tables = []
    if cache is not None:
        for node in cache._nodes():
            for _, p in node.pages:
                refs[p] += 1
    if w is not None:
        for slot, r in enumerate(w.requests):
            if r is None:
                continue
            for p in w.row_pages[slot]:
                refs[p] += 1
            hit = w.row_hits[slot]
            if hit is not None:
                for p in hit.shared:
                    refs[p] += 1
            t = w.row_tables[slot]
            tables.append({int(x) for x in t if int(x) < pool.n_pages})
    return pool, cache, refs, tables


def check_invariants(eng, watch=None):
    """The global pool/radix/COW invariants, checked between engine steps
    (install/retire/COW are atomic within a step from the host's view)."""
    pool, cache, refs, tables = _live_refs(eng)
    if pool is None:
        return
    rc = pool.refcounts()
    free = pool.free_page_ids
    for p in range(pool.n_pages):
        # refcounts == table + tree reference counts, exactly
        assert rc[p] == refs[p], (
            f"page {p}: pool refcount {rc[p]} != reconstructed {refs[p]}")
        # free-list ∩ referenced pages = ∅
        assert (p in free) == (rc[p] == 0), (
            f"page {p}: refcount {rc[p]} but free={p in free}")
    # no page in two tables without a refcount covering every reader
    occ = collections.Counter()
    for t in tables:
        occ.update(t)
    for p, k in occ.items():
        if k > 1:
            assert rc[p] >= k, (
                f"page {p} in {k} tables but refcount {rc[p]}")
    pool.sanity_check()
    if watch is not None:
        watch.observe(eng)


def _page_slices(pools, p):
    """Host copies of physical page ``p`` from every paged k/v buffer."""
    out = []
    for name in sorted(pools):
        for arr in pools[name]:
            a = np.asarray(arr)
            out.append(np.take(a, p, axis=a.ndim - 4).copy())
    return out


class SharedPageWriteMonitor:
    """Write-logging shim for the COW invariant: a page with refcount > 1
    must never be written. The device writers (pool_scatter inside jitted
    cycles, copy_page inside the donated COW jit) cannot be intercepted
    from the host, so the monitor enforces the observable contract
    instead — a shared page's contents are snapshotted when it becomes
    shared and must stay bit-identical at every later observation until
    its refcount drops back to 1."""

    def __init__(self):
        self.snaps = {}
        self.pages_checked = 0

    def observe(self, eng):
        w = eng.wave
        pool = eng.pool if eng.pool is not None else (w.pool if w else None)
        if pool is None or w is None:
            return
        rc = pool.refcounts()
        pools = capture_pools(w.state)
        for p in [q for q in self.snaps if rc[q] <= 1]:
            del self.snaps[p]
        for p in (q for q in range(pool.n_pages) if rc[q] > 1):
            cur = _page_slices(pools, p)
            if p in self.snaps:
                for a, b in zip(self.snaps[p], cur):
                    assert np.array_equal(a, b), (
                        f"shared page {p} (refcount {rc[p]}) was written")
                self.pages_checked += 1
            else:
                self.snaps[p] = cur


# ======================================================= schedule driver ===
def _drive(eng, reqs, rng, watch=None):
    """Scripted schedule: bursty random arrivals interleaved with engine
    steps, invariants checked after every step. Returns the number of
    scheduled steps (engine events the invariants were checked after)."""
    pending = list(reqs)
    steps = 0
    while pending or eng.queue or eng.wave is not None:
        starved = not (eng.queue or eng.wave is not None)
        if pending and (starved or rng.random() < 0.18):
            for _ in range(min(int(rng.integers(3, 9)), len(pending))):
                p, n = pending.pop(0)
                eng.submit(p, max_new=n)
        if eng.wave is None:
            if not eng.queue:
                continue
            eng.start_wave()
        else:
            eng.step()
        steps += 1
        check_invariants(eng, watch)
    return steps


def _stress_traffic(v, rng, n_requests):
    """Random prompts drawn from shared prefix families (hits, splits,
    COW) with random budgets (randomized retire times)."""
    fams = [rng.integers(0, v, size=int(rng.integers(10, 18))).astype(np.int32)
            for _ in range(3)]
    reqs = []
    for _ in range(n_requests):
        f = fams[int(rng.integers(0, len(fams)))]
        cut = int(rng.integers(4, len(f) + 1))
        tail = rng.integers(0, v, size=int(rng.integers(1, 5))).astype(np.int32)
        reqs.append((np.concatenate([f[:cut], tail]),
                     int(rng.integers(2, 9))))
    return reqs


STRESS_SEEDS = [0] + [pytest.param(s, marks=pytest.mark.slow)
                      for s in (1, 2, 3)]


@pytest.mark.parametrize("seed", STRESS_SEEDS)
def test_randomized_pool_invariants(bundle, seed):
    """≥200-step randomized multi-wave schedule with zero refcount /
    free-list / shared-page-write violations (the PR acceptance
    criterion). Seed 0 is the tier-1 gate; the rerolls are the --stress
    variant."""
    rng = np.random.default_rng(seed)
    v = bundle.target_cfg.vocab_size
    reqs = _stress_traffic(v, rng, 120)
    eng = ServingEngine(bundle, batch_size=2, cache_impl="paged",
                        page_size=PAGE, prefix_cache=True,
                        bucket_sizes=(8, 16, 32), pool_headroom=0.75,
                        seed=seed)
    watch = SharedPageWriteMonitor()
    # three drain-to-empty chunks: every chunk boundary is a guaranteed
    # wave turnover, so the schedule always exercises the cross-wave
    # retention path regardless of how the bursty arrivals land
    steps = sum(_drive(eng, reqs[i::3], rng, watch) for i in range(3))
    assert steps >= 200, steps
    assert len(eng.done) == len(reqs)
    assert eng.stats["waves"] >= 3, "schedule never turned a wave over"
    assert eng.stats["prefix_hits"] > 0, "families never produced a hit"
    assert watch.pages_checked > 0, "no shared page was ever observed"
    # drained: every surviving page belongs to the tree, refs balanced
    check_invariants(eng, watch)
    assert eng.pool.pages_in_use == eng.cache.cached_pages


# ==================================================== cross-wave parity ====
def _phased_traffic(bundle, seed=11):
    """Phase 2 prompts extend phase 1's committed strings (prompt +
    greedy answer), so serving phase 2 after a wave turnover exercises
    cross-wave prefix hits."""
    v = bundle.target_cfg.vocab_size
    rng = np.random.default_rng(seed)
    sysp = rng.integers(0, v, size=13).astype(np.int32)
    phase1 = []
    for i in range(3):
        tail = rng.integers(0, v, size=4 + i).astype(np.int32)
        phase1.append((np.concatenate([sysp, tail]), 5))
    phase2 = []
    for p, n in phase1[:2]:
        ans = _ref(bundle, p, n)
        phase2.append((np.concatenate(
            [p, ans, rng.integers(0, v, size=3).astype(np.int32)]), 4))
    return phase1, phase2


def _serve_phases(bundle, phases, **kw):
    eng = ServingEngine(bundle, batch_size=2, cache_impl="paged",
                        page_size=PAGE, **kw)
    marks = []
    for reqs in phases:
        for p, n in reqs:
            eng.submit(p, max_new=n)
        eng.run()
        marks.append(dict(eng.stats))
    return eng, marks


def test_cross_wave_parity_legacy_vs_engine_pool(bundle):
    """Identical multi-wave traffic through per-wave pools (legacy), the
    engine-lifetime pool cache-off, and cache-on: per-request tokens
    must be identical, the cache-on run must hit prefixes cached in the
    PREVIOUS wave, and the outputs must match ``generate_ondevice``."""
    phases = _phased_traffic(bundle)
    e_legacy, _ = _serve_phases(bundle, phases, pool_scope="wave")
    e_off, _ = _serve_phases(bundle, phases)
    e_on, marks = _serve_phases(bundle, phases, prefix_cache=True)
    outs = lambda e: {r.uid: r.out.tolist() for r in e.done}  # noqa: E731
    assert outs(e_legacy) == outs(e_off) == outs(e_on)
    assert e_on.stats["waves"] >= 2
    # hits recorded AFTER the first turnover: phase 2 matched strings the
    # tree committed in phase 1's wave (the resident-server fast path)
    assert (marks[1]["prefix_hit_tokens"]
            > marks[0]["prefix_hit_tokens"]), marks
    # legacy per-wave pools cannot carry prefixes across run() calls
    assert e_legacy.stats["prefix_hits"] == 0
    # per-request parity against each request's standalone greedy decode
    for r in sorted(e_on.done, key=lambda r: r.uid):
        prompt = ([p for ph in phases for p, _ in ph])[r.uid]
        assert np.array_equal(r.out, _ref(bundle, prompt, r.max_new)), r.uid
    # ondevice-loop coverage: same shapes -> one trace, token-identical
    (p1, n1), (p2, n2) = phases[0][0], phases[0][1]
    for p, n, uid in ((p1, n1, 0), (p2, n2, 1)):
        dev = pl.generate_ondevice(bundle, jnp.asarray(p)[None], max_new=n)
        assert np.array_equal(np.asarray(dev["tokens"])[0],
                              outs(e_on)[uid]), uid


def test_cached_pages_survive_wave_turnover(bundle):
    """The borrowed-pool contract end to end: device contents of every
    page the radix tree owns are bit-identical before and after a wave
    turnover (capture_pools -> engine_init -> adopt_pools)."""
    phases = _phased_traffic(bundle, seed=17)
    eng = ServingEngine(bundle, batch_size=2, cache_impl="paged",
                        page_size=PAGE, prefix_cache=True)
    for p, n in phases[0]:
        eng.submit(p, max_new=n)
    eng.run()
    assert eng.wave is None and eng._pools is not None
    tree_pages = sorted({p for node in eng.cache._nodes()
                         for _, p in node.pages})
    assert tree_pages, "phase 1 cached nothing"
    before = {p: _page_slices(eng._pools, p) for p in tree_pages}
    for p, n in phases[1]:
        eng.submit(p, max_new=n)
    assert eng.start_wave()
    survivors = {p for node in eng.cache._nodes() for _, p in node.pages}
    after_pools = capture_pools(eng.wave.state)
    checked = 0
    for p in tree_pages:
        if p not in survivors:
            continue                      # evicted under phase-2 pressure
        for a, b in zip(before[p], _page_slices(after_pools, p)):
            assert np.array_equal(a, b), f"cached page {p} changed"
        checked += 1
    assert checked > 0
    eng.run()
    assert eng.stats["prefix_hits"] > 0


# ================================================= eviction under churn ====
def test_eviction_under_churn_across_waves(bundle):
    """Fill the engine pool across several waves, then admit a worst-case
    cold prompt: LRU eviction reclaims unpinned leaves only (live rows'
    pages are protected by their refcounts — verified by the invariant
    checks after every step), and re-admitting an evicted prefix is a
    clean miss with correct output (no stale page-table reads)."""
    v = bundle.target_cfg.vocab_size
    g = GAMMA
    rng = np.random.default_rng(23)
    fam = [rng.integers(0, v, size=14).astype(np.int32) for _ in range(3)]
    mk_tail = lambda k: rng.integers(0, v, size=k).astype(np.int32)  # noqa
    eng = ServingEngine(bundle, batch_size=2, cache_impl="paged",
                        page_size=PAGE, prefix_cache=True,
                        pool_headroom=0.5)
    watch = SharedPageWriteMonitor()
    # several waves of family traffic fill the tree up to the headroom
    for f in fam:
        reqs = [(np.concatenate([f, mk_tail(3)]), 4),
                (np.concatenate([f, mk_tail(5)]), 4)]
        _drive(eng, reqs, rng, watch)
        assert eng.wave is None
    filled = eng.cache.cached_pages
    assert filled > 0
    # worst-case cold prompt: needs more pages than are free -> eviction
    cold_prompt, cold_new = rng.integers(0, v, size=30).astype(np.int32), 6
    cold_req = Request(uid=-1, prompt=cold_prompt, max_new=cold_new)
    assert eng._pages_needed(cold_req, g) > eng.pool.free_pages
    _drive(eng, [(cold_prompt, cold_new)], rng, watch)
    assert eng.stats["prefix_evictions"] > 0
    # re-admission of an evicted prefix: find a family whose string no
    # longer matches -> clean miss, output still exact
    missed = [f for f in fam if eng.cache.lookup(
        np.concatenate([f, [0]]).astype(np.int32)) is None]
    assert missed, "cold admission evicted nothing from the families"
    misses0 = eng.stats["prefix_misses"]
    probe_prompt, probe_new = np.concatenate([missed[0], mk_tail(2)]), 4
    _drive(eng, [(probe_prompt, probe_new)], rng, watch)
    assert eng.stats["prefix_misses"] > misses0
    done = {r.uid: r for r in eng.done}
    probe_out = done[max(done)]
    assert np.array_equal(probe_out.out, _ref(bundle, probe_prompt,
                                              probe_new)), "stale read"
    check_invariants(eng, watch)


# ============================================== sizing-rule regression =====
def test_pool_sizing_no_refill_double_count(bundle):
    """Regression: the prefix-cache pool previously sized itself as
    ``sum(need)`` over the whole candidate window — counting likely-refill
    candidates' full needs ON TOP of the live set they refill into. The
    engine-global rule pins the budget to live-set + headroom."""
    v = bundle.target_cfg.vocab_size
    rng = np.random.default_rng(31)
    prompts = [rng.integers(0, v, size=9).astype(np.int32)
               for _ in range(6)]                  # 6 identical-need reqs
    g = bundle.spec.gamma

    def sized(prefix_cache, **kw):
        eng = ServingEngine(bundle, batch_size=2, cache_impl="paged",
                            page_size=PAGE, prefix_cache=prefix_cache, **kw)
        for p in prompts:
            eng.submit(p, max_new=4)
        k = eng._pages_needed(eng.queue[0], g)
        assert eng.start_wave()
        return eng, k

    eng, k = sized(True, pool_headroom=0.25)
    live = 2 * k
    assert eng.pool.n_pages == live + int(np.ceil(0.25 * live))
    # the old window-sum rule (4 candidates) would have over-allocated
    assert eng.pool.n_pages < 4 * k
    # cache-off engine pool: live set only, no retention headroom
    eng_off, k = sized(False)
    assert eng_off.pool.n_pages == 2 * k
    # explicit override wins
    eng_ovr, _ = sized(True, pool_pages=4 * k + 1)
    assert eng_ovr.pool.n_pages == 4 * k + 1
    for e in (eng, eng_off, eng_ovr):
        e.run()
        assert len(e.done) == len(prompts)


def test_engine_pool_too_small_raises(bundle):
    """A head request that can never fit the fixed engine pool must fail
    loudly at start_wave, not hang or corrupt."""
    v = bundle.target_cfg.vocab_size
    eng = ServingEngine(bundle, batch_size=2, cache_impl="paged",
                        page_size=PAGE, pool_pages=2)
    eng.submit(np.arange(20, dtype=np.int32) % v, max_new=8)
    with pytest.raises(RuntimeError, match="pool"):
        eng.start_wave()


def test_engine_pool_sized_for_large_queued_request(bundle):
    """Auto-sizing must scan the WHOLE visible queue: a large request
    submitted behind a burst of small ones (beyond the first wave's
    candidate window) still gets a pool it fits and completes — the
    per-wave pools served this traffic, so the engine pool must too."""
    v = bundle.target_cfg.vocab_size
    rng = np.random.default_rng(41)
    prompts = [rng.integers(0, v, size=6).astype(np.int32)
               for _ in range(5)] + [rng.integers(0, v, size=50)
                                     .astype(np.int32)]
    eng = ServingEngine(bundle, batch_size=2, cache_impl="paged",
                        page_size=PAGE)
    for p in prompts:
        eng.submit(p, max_new=4)
    g = GAMMA
    assert (eng._pages_needed(eng.queue[-1], g)
            > 2 * eng._pages_needed(eng.queue[0], g))
    eng.run()
    assert len(eng.done) == len(prompts)
    assert eng._pages_needed(
        Request(uid=-1, prompt=prompts[-1], max_new=4), g) \
        <= eng.pool.n_pages
    for r in eng.done:
        assert np.array_equal(r.out, _ref(bundle, prompts[r.uid],
                                          r.max_new)), r.uid


def test_pool_pages_requires_engine_scope(bundle):
    """An explicit pool_pages override is meaningless for per-wave pools
    (and dense caches) and must be rejected, not silently ignored."""
    with pytest.raises(ValueError, match="pool_pages"):
        ServingEngine(bundle, cache_impl="paged", pool_scope="wave",
                      pool_pages=64)
    with pytest.raises(ValueError, match="pool_pages"):
        ServingEngine(bundle, cache_impl="dense", pool_pages=64)
