"""Per-assigned-architecture smoke tests: reduced config, one forward +
one train-grad step on CPU; asserts shapes and no NaNs (assignment item f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.registry import all_archs, get_config
from repro.models import api


@pytest.mark.parametrize("arch", all_archs())
def test_arch_smoke(arch):
    cfg = get_config(arch, smoke=True)
    params = api.init_model(jax.random.PRNGKey(0), cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    assert n_params > 0
    batch = api.make_batch(jax.random.PRNGKey(1), cfg, batch=2, seq=32)

    loss, grads = jax.value_and_grad(
        lambda p: api.train_loss(p, batch, cfg))(params)
    assert np.isfinite(float(loss)), (arch, loss)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gn)) and float(gn) > 0, arch


@pytest.mark.parametrize("arch", all_archs())
def test_arch_full_config_shape(arch):
    """Full configs build (dataclass level) and report sane param counts."""
    cfg = get_config(arch, smoke=False)
    n = cfg.param_count()
    expected = {
        "qwen2.5-3b": (2e9, 5e9),
        "internlm2-20b": (15e9, 25e9),
        "gemma2-2b": (1.5e9, 4e9),
        "stablelm-3b": (2e9, 4.5e9),
        "recurrentgemma-2b": (2e9, 4.5e9),
        "kimi-k2-1t-a32b": (0.7e12, 1.4e12),
        "grok-1-314b": (2.4e11, 3.9e11),
        "llama-3.2-vision-11b": (8e9, 14e9),
        "whisper-medium": (2.4e8, 1.2e9),
        "rwkv6-1.6b": (1.2e9, 2.4e9),
    }[arch]
    assert expected[0] < n < expected[1], (arch, f"{n:.3e}")
