"""Radix prefix-cache subsystem: tree match/insert/split/evict and page
refcount semantics (host-side), COW correctness under drafter+verify
commits, pinned-page safety, prefix-aware serving token identity, and
prompt-length bucketing of the donated install."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import SpecConfig
from repro.core import pipeline as pl
from repro.core.drafter import drafter_init
from repro.models import kvcache as kvc
from repro.models import lm
from repro.serving.engine import ServingEngine
from repro.serving.prefix_cache import PrefixCache

from conftest import tiny_target, tiny_drafter, pure_greedy

GAMMA = 4
PAGE = 8


@pytest.fixture(scope="module")
def bundle():
    tcfg = tiny_target(vocab=61, dtype="float32")
    dcfg = tiny_drafter(vocab=61, gamma=GAMMA, dtype="float32",
                        target_cfg=tcfg)
    tp = lm.lm_init(jax.random.PRNGKey(0), tcfg)
    d1 = drafter_init(jax.random.PRNGKey(1), dcfg)
    d2 = drafter_init(jax.random.PRNGKey(2), dcfg)
    spec = SpecConfig(gamma=GAMMA, top_k_branches=2, mode="d2sd")
    return pl.SpecBundle(tcfg, dcfg, dcfg, spec, tp, d1, d2)


def _ref(bundle, prompt, n):
    return np.asarray(pure_greedy(bundle.target_params, bundle.target_cfg,
                                  jnp.asarray(prompt)[None], n))[0]


# ===================================================== host-side radix ====
def _insert_string(cache: PrefixCache, pool: kvc.PagePool, tokens):
    """Simulate a retired row: allocate private pages for the uncached
    suffix of ``tokens``, build its row table, insert. Returns the table."""
    tokens = np.asarray(tokens, np.int32)
    hit = cache.lookup(np.concatenate([tokens, [999]]))  # uncapped-ish match
    shared = hit.shared if hit else []
    n_total = kvc.pages_for(len(tokens), pool.page_size)
    priv = pool.alloc(n_total - len(shared))
    assert priv is not None
    if hit:
        cache.acquire(hit)
        cache.release_partial(hit)
    table = pool.row_table(shared + priv, max_pages=n_total)
    donated = cache.insert(tokens, table, private=set(priv),
                           min_donate_idx=len(shared))
    if hit:
        cache.release(hit)
    leftover = [p for p in priv if p not in donated]
    if leftover:
        pool.free(leftover)
    return table


def test_radix_match_insert_roundtrip():
    pool = kvc.PagePool(12, PAGE)
    cache = PrefixCache(pool)
    s = np.arange(100, 120, dtype=np.int32)          # 20 tokens, 3 pages
    assert cache.lookup(s) is None                    # empty tree
    t = _insert_string(cache, pool, s)
    # full-string prompt: match capped at P-1 (one suffix token must stay)
    hit = cache.lookup(s)
    assert hit.length == 19
    assert hit.shared == [int(t[0]), int(t[1])]       # 2 full pages
    assert hit.partial == int(t[2])                   # position 19's page
    # page-aligned prefix: no COW source
    hit16 = cache.lookup(s[:17])
    assert hit16.length == 16 and hit16.partial is None
    assert hit16.shared == [int(t[0]), int(t[1])]
    # divergent first token: miss
    assert cache.lookup(np.asarray([7, 8, 9], np.int32)) is None
    # fully cached reinsert donates nothing and frees the duplicates
    free0 = pool.free_pages
    _insert_string(cache, pool, s)
    assert pool.free_pages == free0


def test_radix_split_and_override_pages():
    pool = kvc.PagePool(16, PAGE)
    cache = PrefixCache(pool)
    a = np.arange(100, 118, dtype=np.int32)           # 18 tokens
    ta = _insert_string(cache, pool, a)
    # second string diverges mid-edge at token 10 (inside page 1)
    b = np.concatenate([a[:10], np.arange(300, 312, dtype=np.int32)])
    tb = _insert_string(cache, pool, b)
    assert cache.n_nodes == 3                         # split upper + 2 leaves
    # matching a's full string still resolves a's own pages
    ha = cache.lookup(a)
    assert ha.length == 17
    assert ha.shared == [int(ta[0]), int(ta[1])]
    # matching b resolves the COW override for page 1, not a's page
    hb = cache.lookup(b)
    assert hb.length == len(b) - 1
    assert hb.shared[0] == int(ta[0])                 # shared page 0
    assert hb.shared[1] == int(tb[1]) != int(ta[1])   # b's override copy
    # the partially-matched upper node's page stays with the upper half:
    # a prompt diverging inside page 0 still finds page 0
    h = cache.lookup(np.concatenate([a[:5], [999, 998]]).astype(np.int32))
    assert h.length == 5 and h.partial == int(ta[0]) and h.shared == []


def test_radix_lru_eviction_order_and_refusal():
    pool = kvc.PagePool(6, PAGE)
    cache = PrefixCache(pool)
    s1 = np.arange(100, 116, dtype=np.int32)          # 2 pages
    s2 = np.arange(200, 216, dtype=np.int32)          # 2 pages
    _insert_string(cache, pool, s1)
    t2 = _insert_string(cache, pool, s2)
    assert pool.free_pages == 2
    cache.lookup(s1)                                  # s1 most recently used
    assert cache.evictable_pages() == 4
    # pin s2 (a row reads its pages) -> only s1 is reclaimable
    hit2 = cache.lookup(s2[:9])
    cache.acquire(hit2)
    assert cache.evictable_pages() == 2
    # pressure for 5 free pages can only reach 4 (s1) -> refuse, but the
    # unpinned LRU leaf (s1, older use BUT s1 was just looked up...) —
    # s2 is pinned so s1 goes regardless of LRU order
    assert not cache.evict_for(5)
    assert pool.free_pages == 4 and cache.evictions == 1
    assert cache.lookup(s1) is None                   # s1 evicted
    assert cache.lookup(s2[:9]).shared == hit2.shared  # s2 survived (pinned)
    # release the pin: now s2 is evictable too
    cache.release_partial(hit2)
    cache.release(hit2)
    assert cache.evict_for(6)
    assert pool.free_pages == 6 and cache.lookup(s2[:9]) is None
    pool.sanity_check()


def test_pageless_split_leaf_evicted_under_inflight_hit():
    """Regression: a _split can leave the LOWER half with zero pages
    (every page start falls before the split point), and such a node
    cannot be pinned through page refcounts. Evicting it while a row's
    full-length hit is in flight shortens the retire-time walk below the
    row's shared boundary; insert's donation must be clamped to the
    row's private span (min_donate_idx), not re-derived from the walk."""
    page = 4
    pool = kvc.PagePool(20, page)
    cache = PrefixCache(pool)
    a = np.arange(100, 108, dtype=np.int32)     # page-aligned length 8
    _insert_string(cache, pool, a)
    # diverge inside a's LAST page -> split at 6 leaves the lower half
    # [6, 8) with no pages (idx0/idx1 both start before the split)
    b = np.concatenate([a[:6], np.asarray([7, 7, 7, 7], np.int32)])
    _insert_string(cache, pool, b)
    # in-flight row with a full-length hit on a's string
    prompt = np.concatenate([a, np.asarray([9], np.int32)])
    committed = np.concatenate([prompt, np.asarray([9, 9, 9], np.int32)])
    hit = cache.lookup(prompt)
    assert hit.length == 8 and len(hit.shared) == 2
    cache.acquire(hit)
    n_total = kvc.pages_for(len(committed) + 2, page)
    priv = pool.alloc(n_total - len(hit.shared))
    cache.release_partial(hit)
    table = pool.row_table(hit.shared + priv, n_total)
    # maximal pressure: every unpinned leaf goes, INCLUDING the page-less
    # lower node on the hit's matched path (pinning must refuse the rest)
    assert not cache.evict_for(pool.n_pages + 1)
    donated = cache.insert(committed, table, private=set(priv),
                           min_donate_idx=len(hit.shared))
    cache.release(hit)
    pool.free([p for p in priv if p not in donated])
    pool.sanity_check()
    assert donated and donated <= set(priv)
    # the reinserted string resolves end to end: shared pages via the
    # surviving pinned owner, private suffix via the new child
    h2 = cache.lookup(np.concatenate([committed, [11]]).astype(np.int32))
    assert h2.length == len(committed)
    assert h2.shared[:2] == hit.shared          # still the donor's pages


def test_radix_eviction_pressure_stress():
    """Randomized interleaving of admissions / retires / insertions under
    a deliberately tight pool: LRU eviction fires while hit paths are in
    flight (including partially pinned chains whose tail leaf is
    evictable), and the donation invariant — insert never hands the tree
    a page the row does not own — must hold throughout; refcounts must
    balance at drain."""
    rng = np.random.default_rng(0)
    page = 4
    pool = kvc.PagePool(48, page)
    cache = PrefixCache(pool)
    # tiny alphabet + shared base strings -> deep overlap, frequent splits
    base = [rng.integers(0, 3, size=int(rng.integers(6, 30))).astype(np.int32)
            for _ in range(6)]
    live = []                       # (hit, priv, table, committed)

    def retire(entry):
        hit, priv, table, toks = entry
        donated = cache.insert(toks, table, private=set(priv),
                               min_donate_idx=len(hit.shared) if hit else 0)
        if hit:
            cache.release(hit)
        leftover = [p for p in priv if p not in donated]
        if leftover:
            pool.free(leftover)

    denied = 0
    for _ in range(300):
        if live and (len(live) >= 4 or rng.random() < 0.45):
            retire(live.pop(int(rng.integers(0, len(live)))))
            continue
        b = base[int(rng.integers(0, len(base)))]
        prompt = np.concatenate(
            [b[: int(rng.integers(1, len(b) + 1))],
             rng.integers(0, 3, size=int(rng.integers(1, 6))).astype(np.int32)])
        committed = np.concatenate(
            [prompt, rng.integers(0, 3,
                                  size=int(rng.integers(0, 8))).astype(np.int32)])
        n_total = kvc.pages_for(len(committed) + 3, page)  # alloc headroom
        hit = cache.lookup(prompt)
        if hit:
            cache.acquire(hit)
        n_new = n_total - (len(hit.shared) if hit else 0)
        if pool.free_pages < n_new:
            cache.evict_for(n_new)
        priv = pool.alloc(n_new)
        if priv is None:                   # admission denied, give hit back
            if hit:
                cache.release_partial(hit)
                cache.release(hit)
            denied += 1
            continue
        table = pool.row_table((hit.shared if hit else []) + priv, n_total)
        if hit:
            cache.release_partial(hit)     # host analogue of post-COW drop
        live.append((hit, priv, table, committed))
    while live:
        retire(live.pop())
    pool.sanity_check()
    assert cache.evictions > 0             # pressure really fired eviction
    # every allocated page is exactly the tree's (all row refs released)
    assert pool.pages_in_use == cache.cached_pages


def test_page_pool_refcount_edge_cases():
    pool = kvc.PagePool(4, PAGE)
    # exhaustion mid-admission: no partial grant, state unchanged
    a = pool.alloc(3)
    assert pool.alloc(2) is None and pool.free_pages == 1
    # sharing lifecycle: second owner keeps the page allocated
    pool.incref([a[0]])
    pool.free([a[0]])
    assert pool.refcount(a[0]) == 1 and pool.free_pages == 1
    pool.free([a[0]])
    assert pool.refcount(a[0]) == 0 and pool.free_pages == 2
    # double free / refcount underflow
    with pytest.raises(AssertionError):
        pool.free([a[0]])
    # incref of a free page is meaningless
    with pytest.raises(AssertionError):
        pool.incref([a[0]])
    # foreign page ids
    with pytest.raises(AssertionError):
        pool.free([99])
    pool.sanity_check()


# ============================================== serving: COW + identity ====
def _serve(bundle, reqs, **kw):
    eng = ServingEngine(bundle, batch_size=2, cache_impl="paged",
                        page_size=PAGE, **kw)
    for p, n in reqs:
        eng.submit(p, max_new=n)
    stats = eng.run()
    return eng, stats


def test_prefix_serving_token_identity_and_cow(bundle):
    """Shared-system-prompt fleet: cache-on serving is token-identical to
    cache-off AND to standalone greedy decoding, while sharing pages
    (hits, COW copies, prefill tokens saved all exercised) — the PR
    acceptance criterion. Hit rows decode *concurrently* with other live
    rows, so drafter feature-cache extension and verify KV commits both
    run against shared (refcount > 1) prefix pages without touching
    them."""
    v = bundle.target_cfg.vocab_size
    rng = np.random.default_rng(3)
    sysp = rng.integers(0, v, size=19).astype(np.int32)
    reqs = []
    for i in range(5):
        tail = rng.integers(0, v, size=4 + i).astype(np.int32)
        reqs.append((np.concatenate([sysp, tail]), 4 + (i % 3)))
    e_off, s_off = _serve(bundle, reqs, prefix_cache=False)
    e_on, s_on = _serve(bundle, reqs, prefix_cache=True)
    outs = lambda e: {r.uid: r.out.tolist() for r in e.done}  # noqa: E731
    assert outs(e_off) == outs(e_on)
    for r in e_on.done:
        assert np.array_equal(r.out, _ref(bundle, reqs[r.uid][0],
                                          r.max_new)), r.uid
    assert s_on["prefix_hits"] > 0
    assert s_on["prefill_tokens_saved"] > 0
    assert s_on["cow_copies"] > 0
    assert s_on["prefix_hit_tokens"] >= s_on["prefix_hits"] * (len(sysp) - 1)
    # cache-off engine never hits
    assert s_off["prefix_hits"] == 0 and s_off["cow_copies"] == 0


def test_prefix_serving_multiturn_hits_generated_tokens(bundle):
    """Multi-turn chat: turn-2 prompts extend turn-1's prompt+answer, so
    matches reach into the *generated* region the retired request
    committed (insert-at-retire covers decode-committed pages, not just
    the prefill)."""
    v = bundle.target_cfg.vocab_size
    rng = np.random.default_rng(5)
    t1 = [(rng.integers(0, v, size=7 + 2 * i).astype(np.int32), 5)
          for i in range(2)]
    t2 = []
    for p, n in t1:
        out = _ref(bundle, p, n)
        t2.append((np.concatenate(
            [p, out, rng.integers(0, v, size=4).astype(np.int32)]), 4))
    reqs = t1 + t2
    e_on, s_on = _serve(bundle, reqs, prefix_cache=True)
    for r in e_on.done:
        assert np.array_equal(r.out, _ref(bundle, reqs[r.uid][0],
                                          r.max_new)), r.uid
    # hits must extend beyond the turn-1 prompts into generated tokens:
    # each turn-2 match covers prompt + (max_new - 1) committed outputs
    min_t2_hit = sum(len(p) + n - 1 for p, n in t1)
    assert s_on["prefix_hit_tokens"] >= min_t2_hit
    assert s_on["prefix_hits"] >= len(t2)


def test_bucketed_install_bounds_traces(bundle):
    """Prompt-length bucketing: distinct donated-install traces stay
    O(buckets) under varying prompt lengths, token output unchanged."""
    v = bundle.target_cfg.vocab_size
    rng = np.random.default_rng(7)
    reqs = [(rng.integers(0, v, size=5 + i).astype(np.int32), 3)
            for i in range(7)]                        # 7 distinct lengths
    e_exact, s_exact = _serve(bundle, reqs, prefix_cache=False,
                              bucket_sizes=None)      # legacy exact installs
    e_bkt, s_bkt = _serve(bundle, reqs, prefix_cache=False,
                          bucket_sizes=(8, 16))
    outs = lambda e: {r.uid: r.out.tolist() for r in e.done}  # noqa: E731
    assert outs(e_exact) == outs(e_bkt)
    assert s_exact["install_traces"] == 7             # one per length
    # one trace per (bucket, install group size): the wave's same-bucket
    # initial pair goes through ONE batched install_rows dispatch (its own
    # trace), refills are singles per bucket — still O(buckets), and
    # strictly fewer donated dispatches than requests
    assert s_bkt["install_traces"] <= 3
    assert s_bkt["install_calls"] < s_bkt["installs"]
    for r in e_bkt.done:
        assert np.array_equal(r.out, _ref(bundle, reqs[r.uid][0],
                                          r.max_new)), r.uid


def test_prefix_cache_requires_paged_and_global(bundle):
    with pytest.raises(ValueError, match="paged"):
        ServingEngine(bundle, cache_impl="dense", prefix_cache=True)
    tcfg = tiny_target(vocab=61, dtype="float32",
                       layer_pattern=("local", "global"), sliding_window=16)
    b2 = pl.SpecBundle(tcfg, bundle.d1_cfg, bundle.d2_cfg, bundle.spec,
                       bundle.target_params, bundle.d1_params,
                       bundle.d2_params)
    with pytest.raises(ValueError, match="global"):
        ServingEngine(b2, cache_impl="paged", prefix_cache=True)
