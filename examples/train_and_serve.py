"""End-to-end driver (deliverable b): pretrain a small target on the
synthetic mixture, distill DFlash + VP drafters from its rollouts, then
serve a batch of requests through the D2SD engine and report acceptance +
throughput.

    PYTHONPATH=src python examples/train_and_serve.py [--steps N]
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.config.base import SpecConfig
from repro.configs.paper_target import drafter_small, smoke
from repro.core import pipeline as pl
from repro.data.synthetic import SyntheticDataset
from repro.serving.engine import ServingEngine
from repro.training import distill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--gamma", type=int, default=8)
    args = ap.parse_args()

    tcfg = smoke()
    print("== pretraining target ==")
    tparams, m = distill.pretrain_target(tcfg, steps=args.steps, batch=16,
                                         seq_len=128)
    print(f"target loss {m[-1]['loss']:.3f}")

    print("== rollouts + drafter distillation ==")
    ds = SyntheticDataset("math", 1, 64, seed=5)
    prompts = ds.prompts(16, 24)
    rollouts = distill.generate_rollouts(tparams, tcfg, prompts, 96)
    dcfg = drafter_small(gamma=args.gamma)
    d1, _ = distill.train_drafter(dcfg, tparams, tcfg, rollouts, vp=False,
                                  steps=args.steps, batch=16)
    d2, _ = distill.train_drafter(dcfg, tparams, tcfg, rollouts, vp=True,
                                  steps=args.steps, batch=16)

    print("== serving ==")
    spec = SpecConfig(gamma=args.gamma, top_k_branches=3, mode="d2sd")
    bundle = pl.SpecBundle(tcfg, dcfg, dcfg, spec, tparams, d1, d2)
    eng = ServingEngine(bundle, batch_size=8)
    test_prompts = ds.prompts(8, 24, offset=10 ** 7)
    for p in test_prompts:
        eng.submit(p, max_new=64)
    stats = eng.run()
    print(f"served {len(eng.done)} requests: alpha={stats['alpha']:.2f} "
          f"tokens/s={stats['tokens_per_s']:.1f} (CPU)")


if __name__ == "__main__":
    main()
