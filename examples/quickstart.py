"""Quickstart: D2SD speculative decoding end-to-end in ~a minute on CPU.

Builds a tiny random target + drafters, runs the full dual-diffusion-draft
pipeline (first draft -> top-K unmask -> VP second draft -> cascade verify)
and shows the lossless-greedy property: the speculative output equals plain
greedy decoding token-for-token even with untrained drafters.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ModelConfig, SpecConfig
from repro.core import pipeline as pl
from repro.core.drafter import DrafterConfig, drafter_init
from repro.models import lm


def main():
    vocab = 199
    tcfg = ModelConfig(num_layers=4, d_model=128, num_heads=4,
                       num_kv_heads=2, d_ff=256, vocab_size=vocab,
                       max_seq_len=512, remat=False, dtype="float32")
    dcfg = DrafterConfig(d_model=64, num_layers=2, num_heads=2,
                         num_kv_heads=2, d_ff=128, vocab_size=vocab,
                         target_feature_dim=3 * tcfg.d_model, gamma=8,
                         dtype="float32")

    tp = lm.lm_init(jax.random.PRNGKey(0), tcfg)
    d1 = drafter_init(jax.random.PRNGKey(1), dcfg)
    d2 = drafter_init(jax.random.PRNGKey(2), dcfg)
    spec = SpecConfig(gamma=8, top_k_branches=3, mode="d2sd")
    bundle = pl.SpecBundle(tcfg, dcfg, dcfg, spec, tp, d1, d2)

    prompts = jax.random.randint(jax.random.PRNGKey(3), (2, 12), 3, vocab)
    print("running D2SD generate (gamma=8, K=3)...")
    out = pl.generate(bundle, prompts, max_new=24,
                      key=jax.random.PRNGKey(7))
    print(f"cycles: {out['n_cycles']}  alpha (tokens/cycle): "
          f"{out['alpha']:.2f}")
    print("tokens[0]:", out["tokens"][0])

    # same decode, but the whole loop fused on device (lax.while_loop):
    dev = pl.generate_ondevice(bundle, prompts, max_new=24,
                               key=jax.random.PRNGKey(7))
    assert np.array_equal(out["tokens"], np.asarray(dev["tokens"]))
    print("on-device while_loop path: token-identical to host loop")

    # lossless check vs plain greedy decoding
    states = lm.init_states(tcfg, 2, 64)
    o = lm.forward(tp, prompts, tcfg, states=states, write_kv=True,
                   remat=False)
    states, tok = o["states"], jnp.argmax(o["logits"][:, -1], -1)
    ref = [tok]
    for _ in range(23):
        o = lm.forward(tp, tok[:, None].astype(jnp.int32), tcfg,
                       states=states, write_kv=True,
                       attend_cache_on_write=True, remat=False)
        states, tok = o["states"], jnp.argmax(o["logits"][:, -1], -1)
        ref.append(tok)
    ref = np.asarray(jnp.stack(ref, 1))
    assert np.array_equal(out["tokens"], ref), "losslessness violated!"
    print("lossless greedy check: PASSED (speculative == plain greedy)")


if __name__ == "__main__":
    main()
