"""Example: lower + compile one serve_step and one train_step against the
production 512-chip multi-pod mesh and print the compiled memory/roofline
summary (the launch-scripts entry point for the full sweep is
``python -m repro.launch.dryrun --all``).

    PYTHONPATH=src python examples/multipod_dryrun.py [--arch qwen2.5-3b]
"""
import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    args = ap.parse_args()
    from repro.launch.dryrun import run_cell
    for shape in ("train_4k", "decode_32k"):
        rec = run_cell(args.arch, shape, "multi")
        print(json.dumps({k: rec[k] for k in
                          ("arch", "shape", "mesh", "ok", "memory", "terms")
                          if k in rec}, indent=2))


if __name__ == "__main__":
    main()
