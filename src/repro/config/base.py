"""Config system: dataclass configs for models, parallelism, training, serving.

Every architecture in ``repro.configs`` builds a :class:`ModelConfig`;
the D2SD engine additionally takes a :class:`SpecConfig`.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Sequence, Tuple


class AttnKind(str, enum.Enum):
    GLOBAL = "global"          # full causal attention
    LOCAL = "local"            # sliding-window causal attention
    RECURRENT = "recurrent"    # RG-LRU block (attention-free)
    RWKV = "rwkv"              # RWKV6 time-mix (attention-free)
    CROSS = "cross"            # cross-attention to external context (VLM / enc-dec)


class Family(str, enum.Enum):
    DENSE = "dense"
    MOE = "moe"
    HYBRID = "hybrid"
    SSM = "ssm"
    VLM = "vlm"
    AUDIO = "audio"


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25
    # "einsum": GShard one-hot dispatch (small configs / smoke tests).
    # "all_to_all": shard_map EP dispatch (production meshes).
    dispatch: str = "einsum"
    # DeepSeek-style shared experts that every token passes through.
    num_shared_experts: int = 0
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: Family = Family.DENSE

    num_layers: int = 4
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: Optional[int] = None          # default d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 512

    # Layer pattern, repeated cyclically over depth, e.g.
    # ("local","global") for gemma2, ("recurrent","recurrent","local") for
    # recurrentgemma, ("rwkv",) for rwkv6. Cross-attn interleave handled by
    # ``cross_attn_every`` (a cross block is *inserted* after every k-th layer).
    layer_pattern: Tuple[str, ...] = ("global",)
    sliding_window: int = 4096
    logit_softcap: Optional[float] = None      # gemma2 final-logit softcap
    attn_softcap: Optional[float] = None       # gemma2 attention-logit softcap

    # MLP
    mlp_act: str = "silu"                      # silu => SwiGLU; gelu => GeGLU-ish dense
    mlp_gated: bool = True

    # Attention details
    qkv_bias: bool = False                     # qwen2-style QKV bias
    rope_theta: float = 10000.0
    qk_norm: bool = False

    # MoE (None => dense FFN)
    moe: Optional[MoEConfig] = None

    # Encoder-decoder (whisper): encoder stack config
    is_encoder_decoder: bool = False
    enc_num_layers: int = 0
    enc_max_len: int = 1500

    # VLM / cross attention
    cross_attn_every: int = 0                  # 0 = no cross-attn layers
    num_vision_tokens: int = 0                 # stub patch-embedding count

    # RWKV / recurrent
    rwkv_head_dim: int = 64
    rglru_width: Optional[int] = None          # RG-LRU recurrence width (d_model default)
    conv1d_width: int = 4                      # temporal conv in recurrent block

    # KV-cache read-path implementation for decode/verify steps.
    #   "gather": materialize a dense logical view via kvcache.pool_view
    #             (paged) / read the ring buffer (dense) and attend on it.
    #   "pallas": call the cascade Pallas kernels directly on the cache
    #             buffers (paged: pool + page table, no per-cycle gather).
    # jit-static: configs ride in SpecBundle aux_data, so flipping this
    # retraces the cycle. Token-identical to "gather" (interpret mode off
    # TPU). Rolling local layers and attention-free blocks always use the
    # plain path regardless of this setting.
    attn_impl: str = "gather"

    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True                         # activation checkpoint per block
    remat_policy: str = "full"                 # full | dots | none
    scan_layers: bool = True                   # lax.scan over layer stack
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    use_post_norm: bool = False                # gemma2 sandwich norm

    max_seq_len: int = 8192

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_heads % self.num_kv_heads == 0, (
            f"num_heads={self.num_heads} not divisible by kv={self.num_kv_heads}")
        assert self.attn_impl in ("gather", "pallas"), (
            f"attn_impl={self.attn_impl!r} not in ('gather', 'pallas')")

    # ---- derived ----
    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    def pattern_for_depth(self) -> Tuple[str, ...]:
        p = self.layer_pattern
        reps = (self.num_layers + len(p) - 1) // len(p)
        return tuple((p * reps)[: self.num_layers])

    @property
    def is_attention_free(self) -> bool:
        kinds = set(self.pattern_for_depth())
        return kinds <= {"recurrent", "rwkv"}

    @property
    def is_subquadratic(self) -> bool:
        """True if no layer does full global attention (long_500k eligible)."""
        kinds = set(self.pattern_for_depth())
        return "global" not in kinds

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, dff, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        n_q = self.num_heads * hd
        n_kv = self.num_kv_heads * hd
        total = v * d  # embed
        if not self.tie_embeddings:
            total += v * d  # lm head
        per_layer = {}
        attn = d * n_q + 2 * d * n_kv + n_q * d
        if self.qkv_bias:
            attn += n_q + 2 * n_kv
        ffn_dense = d * dff * (3 if self.mlp_gated else 2)
        if self.moe is not None:
            ffn = self.moe.num_experts * ffn_dense + d * self.moe.num_experts
            ffn += self.moe.num_shared_experts * ffn_dense
        else:
            ffn = ffn_dense
        rec = 0
        if "recurrent" in self.pattern_for_depth():
            w = self.rglru_width or d
            rec = 2 * d * w + w * d + 2 * w + self.conv1d_width * w
        rwkv = 0
        if "rwkv" in self.pattern_for_depth():
            rwkv = 4 * d * d + 2 * d * dff  # rough: time-mix + channel-mix
        norms = 2 * d
        for kind in self.pattern_for_depth():
            if kind in ("global", "local"):
                per = attn + ffn + norms
            elif kind == "recurrent":
                per = rec + ffn_dense + norms
            elif kind == "rwkv":
                per = rwkv + norms
            else:
                per = attn + ffn + norms
            per_layer[kind] = per
            total += per
        if self.cross_attn_every:
            n_cross = self.num_layers // self.cross_attn_every
            total += n_cross * (attn + norms)
        if self.is_encoder_decoder:
            total += self.enc_num_layers * (attn + ffn_dense + norms)
            total += self.num_layers * (attn + norms)  # decoder cross-attn
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if self.moe is None:
            return self.param_count()
        d, dff = self.d_model, self.d_ff
        dense_ffn = d * dff * (3 if self.mlp_gated else 2)
        inactive = (self.moe.num_experts - self.moe.top_k) * dense_ffn
        return int(self.param_count() - self.num_layers * inactive)


# Built-in draft strategies (repro.core.strategies registry). Plugin
# strategies registered at runtime extend this set — validation checks the
# live registry when it is loaded.
KNOWN_STRATEGIES: Tuple[str, ...] = (
    "d2sd", "dflash", "naive_k", "dflash_second", "eagle")


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """D2SD speculative decoding configuration (paper §3)."""
    gamma: int = 16                 # block size (anchor + gamma-1 drafted)
    top_k_branches: int = 4         # K
    # Drafter conditioning: how many trailing target layers' features feed
    # the FC projection (paper: multi-layer concat).
    feature_layers: int = 3
    # Draft strategy name, dispatched through the repro.core.strategies
    # registry (paper ablations Tables 5/6/7 are the built-in entries).
    mode: str = "d2sd"              # see KNOWN_STRATEGIES + runtime plugins
    third_level: bool = False       # Table 7: stack one more VP level (top-1 each)
    temperature: float = 0.0        # 0 => greedy verification, else lossless sampling
    # VP-Drafter training recipe (Eqs. 6-7)
    prefix_beta: float = 0.8        # truncated-geometric prior on prefix length
    loss_tau: float = 4.0           # anchor-decay temperature in Eq. 7
    # Engine details
    max_target_len: int = 4096

    def __post_init__(self):
        names = KNOWN_STRATEGIES
        if self.mode not in names:
            # Consult the live registry (runtime-registered plugins, future
            # built-ins); imported lazily so config-only users do not pay
            # the core import on the common path.
            try:
                from repro.core import strategies as _strategies
                names = tuple(_strategies.registered_strategies())
            except ImportError:
                pass
        if self.mode not in names:
            raise ValueError(
                f"SpecConfig.mode={self.mode!r} is not a registered draft "
                f"strategy; known: {sorted(names)}")
        if self.gamma < 2:
            raise ValueError(
                "gamma must cover anchor + >=1 drafted token")
        if self.top_k_branches < 1:
            raise ValueError("top_k_branches must be >= 1")

    @property
    def strategy(self) -> str:
        """Registry name of the draft strategy (alias of ``mode``)."""
        return self.mode


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    data: int = 1
    model: int = 1
    pod: int = 1
    # What the pod axis means: "dp" (extra data parallel) or "pipeline".
    pod_role: str = "dp"


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"             # adamw | adamw8bit | adafactor
    lr: float = 3e-4
    warmup_steps: int = 20
    total_steps: int = 300
    weight_decay: float = 0.01
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    grad_accum: int = 1
    # int8 gradient all-reduce with error feedback (distributed/collectives.py)
    compress_grads: bool = False


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    batch_size: int = 32
    seq_len: int = 128
    seed: int = 0
    optimizer: OptimizerConfig = dataclasses.field(default_factory=OptimizerConfig)
    checkpoint_every: int = 100
    checkpoint_dir: str = "/tmp/repro_ckpt"
    async_checkpoint: bool = False
    log_every: int = 10
    # fault tolerance
    max_restarts: int = 3


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned (shape) cell: train / prefill / decode / long-decode."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


ASSIGNED_SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", 4096, 256, "train"),
    ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    ShapeSpec("decode_32k", 32768, 128, "decode"),
    ShapeSpec("long_500k", 524288, 1, "decode"),
)


def shape_by_name(name: str) -> ShapeSpec:
    for s in ASSIGNED_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)
