"""Architecture registry: ``--arch <id>`` lookup.

Each module in ``repro.configs`` registers a full-size config and a reduced
smoke config under the same id.
"""
from __future__ import annotations

import importlib
from typing import Callable, Dict, Tuple

from repro.config.base import ModelConfig

_FULL: Dict[str, Callable[[], ModelConfig]] = {}
_SMOKE: Dict[str, Callable[[], ModelConfig]] = {}

ARCH_IDS = (
    "qwen2.5-3b",
    "internlm2-20b",
    "gemma2-2b",
    "stablelm-3b",
    "recurrentgemma-2b",
    "kimi-k2-1t-a32b",
    "grok-1-314b",
    "llama-3.2-vision-11b",
    "whisper-medium",
    "rwkv6-1.6b",
)

_MODULES = {
    "qwen2.5-3b": "qwen2_5_3b",
    "internlm2-20b": "internlm2_20b",
    "gemma2-2b": "gemma2_2b",
    "stablelm-3b": "stablelm_3b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "grok-1-314b": "grok1_314b",
    "llama-3.2-vision-11b": "llama32_vision_11b",
    "whisper-medium": "whisper_medium",
    "rwkv6-1.6b": "rwkv6_1_6b",
    # paper's own models (reduced-scale analogues)
    "paper-target": "paper_target",
    "paper-drafter": "paper_target",
}


def register(arch_id: str, full: Callable[[], ModelConfig],
             smoke: Callable[[], ModelConfig]) -> None:
    _FULL[arch_id] = full
    _SMOKE[arch_id] = smoke


def _ensure(arch_id: str) -> None:
    if arch_id not in _FULL:
        mod = _MODULES.get(arch_id)
        if mod is None:
            raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
        importlib.import_module(f"repro.configs.{mod}")


def get_config(arch_id: str, smoke: bool = False) -> ModelConfig:
    _ensure(arch_id)
    return (_SMOKE if smoke else _FULL)[arch_id]()


def all_archs() -> Tuple[str, ...]:
    return ARCH_IDS
