"""KV cache + drafter feature cache: functional, sharded, fixed-capacity.

Layout: k/v ``[B, S_max, Hkv, Dh]`` per layer group (stacked over scanned
layers as leading axis ``[L, B, S_max, Hkv, Dh]``); ``length`` is a scalar
int32 (uniform across batch — the serving engine aligns requests per wave;
ragged batching is handled above this layer by the engine's slot map).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain


def init_cache(num_layers: int, batch: int, max_len: int, num_kv_heads: int,
               head_dim: int, dtype=jnp.bfloat16):
    shape = (num_layers, batch, max_len, num_kv_heads, head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "length": jnp.zeros((), jnp.int32),
    }


def cache_layer(cache, idx):
    """View of one (scanned) layer's cache: k/v [B,S,Hkv,Dh]."""
    return cache["k"][idx], cache["v"][idx]


def update_layer(cache, idx, k_new, v_new, start):
    """Write [B,T,Hkv,Dh] at positions [start, start+T) of layer ``idx``."""
    t = k_new.shape[1]
    k = jax.lax.dynamic_update_slice(
        cache["k"], k_new[None].astype(cache["k"].dtype),
        (idx, 0, start, 0, 0))
    v = jax.lax.dynamic_update_slice(
        cache["v"], v_new[None].astype(cache["v"].dtype),
        (idx, 0, start, 0, 0))
    return {**cache, "k": k, "v": v}


def set_length(cache, length):
    return {**cache, "length": jnp.asarray(length, jnp.int32)}


def constrain_cache(cache, kv_seq_sharded: bool = False):
    """Apply sharding: batch over data; seq over model when KV-SP decode."""
    seq_axis = "kv_seq" if kv_seq_sharded else None
    out = dict(cache)
    for key in ("k", "v"):
        out[key] = constrain(cache[key], (None, "batch", seq_axis, "kv_heads", None))
    return out


# --------------------------------------------------------------------------
# Drafter feature cache: projected target features consumed as K/V by every
# drafter layer (DFlash KV injection). Stored post-projection per drafter
# layer: [L_d, B, S_max, Hkv_d, Dh_d] for K and V.
# --------------------------------------------------------------------------

def init_feature_cache(num_layers: int, batch: int, max_len: int,
                       num_kv_heads: int, head_dim: int, dtype=jnp.bfloat16):
    return init_cache(num_layers, batch, max_len, num_kv_heads, head_dim, dtype)
