"""KV-cache storage layer: dense contiguous caches + the paged subsystem.

Two interchangeable storage layouts back every KV-shaped cache in the
engine (target global-attention KV and the drafter feature caches), keyed
by ``cache_impl``:

* ``dense`` — the original layout: per-row contiguous ``[B, S_max, H, D]``
  buffers (stacked over scanned layers / drafter layers as a leading axis).
  Every row reserves worst-case ``S_max`` positions for its lifetime.
* ``paged`` — a **page pool**: one shared buffer of ``pool_pages``
  fixed-size pages ``[P, page, H, D]`` plus a per-row page table
  ``pt [B, max_pages]`` mapping logical page ``j`` of row ``b`` to a
  physical page id. Rows own only the pages a host-side :class:`PagePool`
  allocated to them, so a serving wave reserves memory proportional to the
  *live requests'* lengths instead of ``B * S_max``, retiring a request
  frees its pages, and installing a new request into a slot touches only
  its freshly allocated pages plus one page-table row (no full-state copy).

A paged cache dict is recognized structurally by the presence of the
``"pt"`` key next to ``"k"``/``"v"`` — callers branch on
:func:`is_paged` instead of threading a mode flag through every layer.

Semantics contract (what keeps dense and paged token-identical): the
*logical view* of a paged cache — :func:`pool_view`, physical pages
gathered in page-table order — holds exactly the same values at every
committed position as the dense cache would; positions at or beyond the
row ``length`` are garbage in both layouts and are masked identically by
the attention mask (``kpos < cache_len``), so softmax results agree
bit-for-bit. Writes go through :func:`pool_scatter`, which translates
logical positions to ``(physical page, slot)`` pairs and drops
out-of-allocation writes (``mode="drop"``), touching only the tail
page(s) being appended to.

Local (sliding-window) layers keep their dense rolling buffers in both
modes: their capacity is already window-capped and the rolling position
recovery does not compose with page indirection.

Copy-on-write invariant (prefix sharing)
----------------------------------------
Pages are refcounted by the host :class:`PagePool` so one physical page
can back the same committed prefix in many rows (cross-request prefix
sharing, ``serving/prefix_cache.py``). The contract every writer upholds:

    **a page with refcount > 1 is never written.**

Rows only ever write at logical positions >= their own committed
``length``; a prefix-cache hit installs the matched prefix's pages
read-only (refcount bumped) and the first page the new row *would* write
into — the partially filled tail page of the shared prefix — is first
**copied to a freshly allocated page** (:func:`copy_page`, the COW step)
before the row's page table is patched. Drafter feature-cache extension
and verify KV commits therefore always land in pages the row owns
exclusively (refcount == 1), and shared pages stay bit-frozen until the
last owner releases them.

Pool scope (the borrowed-pool contract)
---------------------------------------
By default the serving engine owns ONE :class:`PagePool` for its whole
lifetime and every wave borrows it: the host allocator (ids, refcounts,
free list) persists untouched across ``start_wave``, and the device-side
pool buffers are carried over via ``core.state.capture_pools`` /
``adopt_pools`` (they are batch-free, so a new wave's geometry only
changes the page table and dense leaves). That is what lets the radix
prefix cache retain committed prefixes across wave turnover — a resident
server stops re-prefilling its system prompts every wave. The legacy
per-wave pool (``pool_scope="wave"``) allocates and drops a fresh pool
per wave and is kept as the A/B reference.

Mesh layout (page identity global, page bytes per-shard)
--------------------------------------------------------
Under a ``use_sharding`` context with a ``kv_seq`` rule, pool payloads
are placed along that mesh axis by :func:`shard_pool` (called from
:func:`init_pool`): the split is WITHIN the page's slot axis — shard
``i`` of ``n`` owns slots ``[i*page_size/n, (i+1)*page_size/n)`` of
EVERY page — so one host-side allocation decision places a page on all
shards at once and nothing above this layer changes: :class:`PagePool`,
refcounts, the radix tree and the page tables keep counting GLOBAL
pages, arrays keep their global logical shapes (all geometry asserts
hold verbatim), and :func:`pool_scatter` / :func:`copy_page` writes stay
plain ``jnp`` ops that GSPMD partitions. The decode read path is the
exception: paged cascade reads — the verify KV layers AND the drafter
feature caches (``core.drafter.drafter_forward``) — run under
``shard_map`` (``distributed.spdecode.sharded_paged_cache_attend``),
where each shard reads only its local pool slice (``pool_view`` gather
or the pos_stride/pos_offset cascade kernel, per ``attn_impl``), masks
by the ABSOLUTE positions its non-contiguous slots represent, and one
float32 LSE ``psum`` merges the per-shard attention stats —
token-identical to the single-device path.
Borrowed pools carry this placement across wave turnover untouched
(``core.state.capture_pools`` / ``adopt_pools``).
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constrain


def init_cache(num_layers: int, batch: int, max_len: int, num_kv_heads: int,
               head_dim: int, dtype=jnp.bfloat16):
    shape = (num_layers, batch, max_len, num_kv_heads, head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "length": jnp.zeros((), jnp.int32),
    }


def cache_layer(cache, idx):
    """View of one (scanned) layer's cache: k/v [B,S,Hkv,Dh]."""
    return cache["k"][idx], cache["v"][idx]


def update_layer(cache, idx, k_new, v_new, start):
    """Write [B,T,Hkv,Dh] at positions [start, start+T) of layer ``idx``."""
    t = k_new.shape[1]
    k = jax.lax.dynamic_update_slice(
        cache["k"], k_new[None].astype(cache["k"].dtype),
        (idx, 0, start, 0, 0))
    v = jax.lax.dynamic_update_slice(
        cache["v"], v_new[None].astype(cache["v"].dtype),
        (idx, 0, start, 0, 0))
    return {**cache, "k": k, "v": v}


def set_length(cache, length):
    return {**cache, "length": jnp.asarray(length, jnp.int32)}


def constrain_cache(cache, kv_seq_sharded: bool = False):
    """Apply sharding: batch over data; seq over model when KV-SP decode."""
    seq_axis = "kv_seq" if kv_seq_sharded else None
    out = dict(cache)
    for key in ("k", "v"):
        out[key] = constrain(cache[key], (None, "batch", seq_axis, "kv_heads", None))
    return out


# ===========================================================================
# Paged subsystem
# ===========================================================================

#: Page-table entry marking an unallocated logical page. Growth-stable:
#: int32 max can never collide with a real page id even if the pool is
#: later grown in place, unlike the old ``n_pages`` sentinel (a pool grown
#: from P to P' pages would silently turn every stale ``P`` sentinel into
#: a live alias of physical page P). Every consumer treats it as
#: out-of-range: reads clamp + mask (:func:`pool_view`, the cascade
#: kernel's ``jnp.minimum(table, n_phys - 1)``), writes drop
#: (:func:`pool_scatter` ``mode="drop"``).
PAGE_SENTINEL = np.iinfo(np.int32).max


def is_paged(cache_dict) -> bool:
    """A cache/state dict is paged iff it carries a page table."""
    return isinstance(cache_dict, dict) and "pt" in cache_dict


def pages_for(n_tokens: int, page_size: int) -> int:
    """Pages needed to hold ``n_tokens`` cache positions."""
    return -(-int(n_tokens) // int(page_size))


def page_geometry(cache_dict):
    """(page_size, max_pages, pool_pages) of a paged cache dict."""
    pool = cache_dict["k"]
    return pool.shape[-3], cache_dict["pt"].shape[-1], pool.shape[-4]


def logical_len(cache_dict) -> int:
    """Logical per-row capacity (max_pages * page_size) of a paged dict."""
    page, max_pages, _ = page_geometry(cache_dict)
    return page * max_pages


def identity_page_table(batch: int, max_pages: int) -> jnp.ndarray:
    """[B, max_pages] table where row ``b`` owns pages
    [b*max_pages, (b+1)*max_pages) — the allocator-free layout used by
    ``generate`` / ``generate_ondevice`` (uniform waves, no churn)."""
    return (jnp.arange(batch, dtype=jnp.int32)[:, None] * max_pages
            + jnp.arange(max_pages, dtype=jnp.int32)[None, :])


def default_page_layout(batch: int, max_len: int, page_size: int,
                        pool_pages=None, page_table=None):
    """Single source of truth for paged-cache sizing defaults.

    Returns ``(pool_pages, page_table)`` with the identity layout filled
    in wherever the caller left None — every paged cache of a wave (target
    KV pools and both feature caches) must derive its geometry through
    this one rule or their page-id spaces silently diverge.
    """
    mp = pages_for(max_len, page_size)
    if page_table is None:
        page_table = identity_page_table(batch, mp)
    if pool_pages is None:
        pool_pages = batch * mp
    return pool_pages, page_table


def init_pool(pool_pages: int, page_size: int, num_kv_heads: int,
              head_dim: int, dtype=jnp.bfloat16, lead: tuple = ()):
    """Zeroed K or V page pool [*lead, P, page, Hkv, Dh] (lead = stacked
    layer axes: drafter layers or scanned periods).

    Under an active mesh with a ``kv_seq`` rule the pool's page *payloads*
    are placed shard-wise along the within-page position axis (shard i of
    P holds slots ``[i*page/P, (i+1)*page/P)`` of every page) while the
    array stays logically global-shaped — page ids, tables, and every
    geometry assert are layout-agnostic. See :func:`shard_pool`.
    """
    pool = jnp.zeros((*lead, pool_pages, page_size, num_kv_heads, head_dim),
                     dtype)
    return shard_pool(pool, lead=len(lead))


def shard_pool(pool, lead: int = 0):
    """Place a page pool's payload bytes along the ``kv_seq`` mesh axis.

    The sharded dim is the within-page position axis (``ndim - 3``); the
    mesh axis is dropped automatically when ``page_size`` is not divisible
    by the axis size (``fit_spec``), and the whole call is a no-op without
    a mesh. Works eagerly (engine pool allocation, adopted buffers) and
    inside jit (``_ondevice_loop``'s traced ``engine_init``).
    """
    from repro.distributed.sharding import shard_put
    return shard_put(pool, (None,) * lead + (None, "kv_seq", None, None))


def _norm_table(table):
    """Page tables are replicated over stacked-layer axes for threading
    convenience; physical indexing always uses one copy [B, max_pages]."""
    while table.ndim > 2:
        table = table[0]
    return table


def pool_view(pool, table):
    """Gather the logical per-row view of a page pool.

    pool [P, page, H, D] (or stacked [L, P, page, H, D]);
    table [B, max_pages] (stacked copies accepted) ->
    [B, MP*page, H, D] (or [L, B, MP*page, H, D]).

    Out-of-range table entries (the :data:`PAGE_SENTINEL` marking
    unallocated logical pages) clamp to the last physical page; the
    garbage they surface sits at logical positions >= the row length and
    is masked by every consumer. This is the jnp reference read path; the
    Pallas cascade kernel reads the pool in place via a page-table
    index_map instead (kernels/cascade_attention.py).
    """
    table = _norm_table(table)
    b, mp = table.shape
    if pool.ndim == 4:
        v = pool[table]                          # [B, MP, page, H, D]
        return v.reshape(b, mp * v.shape[2], *v.shape[3:])
    v = pool[:, table]                           # [L, B, MP, page, H, D]
    return v.reshape(v.shape[0], b, mp * v.shape[3], *v.shape[4:])


def pool_scatter(pool, table, new, pos, valid=None):
    """Write ``new`` at logical positions ``pos`` of each row's paged
    stream — the paged analogue of a tail ``dynamic_update_slice``.

    pool: [P, page, H, D] or stacked [L, P, page, H, D]
    table: [B, max_pages] (stacked copies accepted)
    new:  [B, T, H, D] or [L, B, T, H, D] matching ``pool``
    pos:  [B, T] logical positions; valid: optional [B, T] bool — entries
          that are False (or whose position falls outside the row's table)
          are dropped, never written.

    Only the page(s) covering ``pos`` are touched. The scatter has no
    duplicate indices (deterministic) because every page a row WRITES is
    exclusively its own: rows only write at positions >= their committed
    length, and the COW invariant (module docstring) guarantees those
    positions live in refcount-1 pages — prefix-shared pages (refcount >
    1) are read-only until the last owner releases them.
    """
    table = _norm_table(table)
    page = pool.shape[-3]
    n_phys = pool.shape[-4]
    mp = table.shape[-1]
    pos = jnp.asarray(pos, jnp.int32)
    pidx = pos // page
    slot = pos % page
    ok = (pos >= 0) & (pidx < mp)
    if valid is not None:
        ok &= valid
    phys = jnp.take_along_axis(table, jnp.clip(pidx, 0, mp - 1), axis=1)
    phys = jnp.where(ok, phys, n_phys)           # out of range -> dropped
    new = new.astype(pool.dtype)
    if pool.ndim == 4:
        return pool.at[phys, slot].set(new, mode="drop")
    return pool.at[:, phys, slot].set(new, mode="drop")


def copy_page(pool, src, dst):
    """Copy one physical page's contents ``src -> dst`` (the COW step).

    pool: [..., P, page, H, D] (any stacked leading axes — drafter layers
    or scanned periods); ``src`` / ``dst`` may be traced int32 scalars.
    Used when a prefix-cache hit ends inside a page: the shared partial
    tail page is duplicated into a freshly allocated page before the new
    row's first write, so a page with refcount > 1 is never written.
    """
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)
    page = jax.lax.dynamic_index_in_dim(pool, src, axis=pool.ndim - 4,
                                        keepdims=False)
    return jax.lax.dynamic_update_index_in_dim(pool, page, dst,
                                               axis=pool.ndim - 4)


class PagePool:
    """Host-side refcounted free-list allocator over a page space.

    One pool instance backs either a single wave (legacy per-wave scope)
    or the whole serving engine's lifetime (``pool_scope="engine"``, the
    default): waves *borrow* the pool, so pages the radix prefix cache
    owns — and their device-side contents, carried across waves via
    ``core.state.capture_pools``/``adopt_pools`` — survive wave turnover.

    Pages are interchangeable (no fragmentation): ``alloc`` pops any free
    ids, ``free`` returns them. The serving engine allocates a request's
    worst-case page count at admission (install) and frees it at retire,
    so admission control is one integer comparison against
    :attr:`free_pages` instead of a per-slot ``max_len`` reservation.

    Refcounts make cross-request prefix sharing safe: ``alloc`` hands a
    page out at refcount 1, :meth:`incref` adds a reader (a prefix-cache
    hit splicing the page into another row's table), and :meth:`free` is
    a decref — the page only returns to the free list when its last
    owner lets go. A page with refcount > 1 is shared and must never be
    written (the COW invariant, see module docstring); refcount underflow
    and double frees are hard assertion failures, not silent corruption.
    """

    def __init__(self, n_pages: int, page_size: int):
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        self._free: List[int] = list(range(self.n_pages - 1, -1, -1))
        self._free_set = set(self._free)     # O(1) double-free detection
        self._ref: List[int] = [0] * self.n_pages
        self.peak_in_use = 0

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.n_pages - len(self._free)

    def refcount(self, page: int) -> int:
        assert 0 <= page < self.n_pages, f"foreign page {page}"
        return self._ref[page]

    @property
    def free_page_ids(self):
        """Frozen snapshot of the free page ids (invariant tests: the
        free list and the referenced set must stay disjoint)."""
        return frozenset(self._free_set)

    def refcounts(self) -> List[int]:
        """Snapshot of every page's refcount (invariant tests: refcounts
        must equal the table + radix-tree reference counts)."""
        return list(self._ref)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` free page ids at refcount 1; None (no partial grant)
        if short."""
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self._free_set.difference_update(pages)
        for p in pages:
            self._ref[p] = 1
        self.peak_in_use = max(self.peak_in_use, self.pages_in_use)
        return pages

    def incref(self, pages: Sequence[int]) -> None:
        """Add a reader to allocated pages (prefix sharing). Increffing a
        free page is a bug — there is nothing to share."""
        for p in pages:
            assert 0 <= p < self.n_pages and self._ref[p] > 0, \
                f"incref of free / foreign page {p}"
        for p in pages:
            self._ref[p] += 1

    def free(self, pages: Sequence[int]) -> None:
        """Drop one reference per page; a page returns to the free list
        when its refcount reaches 0. Freeing an already-free page
        (refcount underflow / double free) asserts."""
        for p in pages:
            assert 0 <= p < self.n_pages and p not in self._free_set \
                and self._ref[p] > 0, f"double free / foreign page {p}"
            self._ref[p] -= 1
            if self._ref[p] == 0:
                self._free.append(p)
                self._free_set.add(p)

    def sanity_check(self) -> None:
        """Free-list / refcount consistency (tests + debug)."""
        assert len(self._free) == len(self._free_set)
        for p in range(self.n_pages):
            assert (self._ref[p] == 0) == (p in self._free_set), p

    def row_table(self, pages: Sequence[int], max_pages: int):
        """[max_pages] int32 row table: allocated pages first, then the
        growth-stable :data:`PAGE_SENTINEL` marking unallocated slots —
        reads clamp+mask, writes drop."""
        t = np.full((max_pages,), PAGE_SENTINEL, np.int32)
        t[: len(pages)] = pages
        return t


# --------------------------------------------------------------------------
# Drafter feature cache: projected target features consumed as K/V by every
# drafter layer (DFlash KV injection). Stored post-projection per drafter
# layer: [L_d, B, S_max, Hkv_d, Dh_d] for K and V (dense) or as stacked
# page pools [L_d, P, page, Hkv_d, Dh_d] + one shared page table (paged).
# --------------------------------------------------------------------------

def init_feature_cache(num_layers: int, batch: int, max_len: int,
                       num_kv_heads: int, head_dim: int, dtype=jnp.bfloat16):
    return init_cache(num_layers, batch, max_len, num_kv_heads, head_dim, dtype)
