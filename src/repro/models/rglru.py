"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block:  y = W_out( GeLU(W_gate x) * RGLRU( conv1d( W_branch x ) ) )
RG-LRU: r_t = sigmoid(W_a u_t + b_a)         (recurrence gate)
        i_t = sigmoid(W_x u_t + b_x)         (input gate)
        a_t = exp(c * r_t * log(sigmoid(lam)))  in (0,1),  c = 8
        h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

The diagonal linear recurrence runs as an associative scan (parallel over
time on TPU); decode carries (h, conv buffer) state.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import param as pm
from repro.models.layers import dense

STATE_KEYS = ("rg_h", "conv_buf")
_C = 8.0


def rglru_block_init(key, cfg):
    d = cfg.d_model
    w = cfg.rglru_width or d
    ks = pm.split(key, 6)
    return {
        "w_branch": pm.dense_init(ks[0], d, w),
        "w_gate": pm.dense_init(ks[1], d, w),
        "w_out": pm.dense_init(ks[2], w, d, scale=w ** -0.5),
        "conv_w": pm.trunc_normal(ks[3], (cfg.conv1d_width, w), stddev=0.1),
        "wa": pm.dense_init(ks[4], w, w),
        "ba": pm.zeros((w,)),
        "wx": pm.dense_init(ks[5], w, w),
        "bx": pm.zeros((w,)),
        # lambda init so that a^c = sigmoid(lam)^c is in ~[0.9, 0.999]
        "lam": jnp.linspace(2.0, 6.0, w).astype(jnp.float32),
    }


def rglru_state_init(cfg, batch: int, dtype=jnp.float32) -> Dict[str, jnp.ndarray]:
    w = cfg.rglru_width or cfg.d_model
    return {
        "rg_h": jnp.zeros((batch, w), dtype),
        "conv_buf": jnp.zeros((batch, cfg.conv1d_width - 1, w), dtype),
    }


def _causal_conv1d(x, w, buf, snap_at=None):
    """x: [B,T,W]; w: [K,W] depthwise; buf: [B,K-1,W] left context.

    snap_at: optional [B] — new buffer reflects state after exactly
    ``snap_at`` tokens (for partial-acceptance commit), else after all T.
    """
    k = w.shape[0]
    t = x.shape[1]
    xx = jnp.concatenate([buf.astype(x.dtype), x], axis=1)   # [B, K-1+T, W]
    out = sum(xx[:, i:i + t] * w[i].astype(x.dtype) for i in range(k))
    if k > 1:
        if snap_at is None:
            new_buf = xx[:, -(k - 1):]
        else:
            idx = snap_at[:, None] + jnp.arange(k - 1)[None, :]
            new_buf = jnp.take_along_axis(xx, idx[..., None], axis=1)
    else:
        new_buf = buf
    return out, new_buf


def rglru_block(p, x, cfg, state: Optional[Dict] = None, snap_at=None):
    """x: [B,T,d] -> (y [B,T,d], new_state).

    snap_at: optional [B] in [1, T] — returned state corresponds to having
    consumed exactly snap_at tokens (outputs still cover all T).
    """
    b, t, d = x.shape
    w_dim = cfg.rglru_width or d
    st = state or rglru_state_init(cfg, b)
    gate = jax.nn.gelu(dense(p["w_gate"], x))
    u = dense(p["w_branch"], x)
    u, conv_buf = _causal_conv1d(u, p["conv_w"], st["conv_buf"],
                                 snap_at=snap_at)

    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(dense(p["wa"], uf) + p["ba"])
    i = jax.nn.sigmoid(dense(p["wx"], uf) + p["bx"])
    log_a1 = jax.nn.log_sigmoid(p["lam"].astype(jnp.float32))   # [W]
    log_a = _C * r * log_a1[None, None, :]                      # [B,T,W]
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12)) * (i * uf)

    # h_t = a_t h_{t-1} + b_t with h_{-1} = state: fold state into b_0
    b0 = gated_in[:, 0] + a[:, 0] * st["rg_h"].astype(jnp.float32)
    bs = jnp.concatenate([b0[:, None], gated_in[:, 1:]], axis=1)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_sc, h = jax.lax.associative_scan(combine, (a, bs), axis=1)
    y = dense(p["w_out"], (gate.astype(jnp.float32) * h).astype(x.dtype))
    if snap_at is None:
        h_fin = h[:, -1]
    else:
        h_fin = jnp.take_along_axis(
            h, jnp.clip(snap_at - 1, 0, t - 1)[:, None, None], axis=1)[:, 0]
    new_state = {"rg_h": h_fin,
                 "conv_buf": conv_buf.astype(st["conv_buf"].dtype)}
    return y, new_state
