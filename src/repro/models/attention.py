"""Attention: GQA projections + mask construction + memory-efficient impls.

Three implementations share one semantics (validated against each other):

* ``dense``   — materializes [Tq, Tkv] scores; tiny shapes / oracle.
* ``chunked`` — flash-style running-softmax over KV chunks in pure JAX
                (lax.scan); O(Tq * chunk) memory; used on compile paths so the
                dry-run HLO never materializes S^2 scores.
* ``pallas``  — TPU kernels in ``repro.kernels`` (flash fwd/bwd, cascade).

Mask semantics (composable):
  causal with query offset ``q_offset`` (prefill/decode with cache),
  sliding window, gemma2 attention-logit softcap, explicit extra mask
  (tree/bidirectional-block), and KV length masking for padded caches.

Cache READ path (``ModelConfig.attn_impl``, distinct from the ``impl``
call parameter above): "gather" (default) materializes the dense logical
view of a paged cache via ``kvcache.pool_view`` and attends over it with
one of the three impls; "pallas" routes decode/verify steps on paged
global layers straight to ``kernels.ops.cascade_attention_paged`` (pool
buffers + page table, no dense gather — see ``models/blocks.py``).
``attn_impl`` is a jit-static carried by the config (SpecBundle registers
configs as pytree aux_data), token-identical by tier-1 assertion, and
falls back to interpret mode off-TPU.

Coverage matrix under ``attn_impl="pallas"``:

* paged GLOBAL layers — ``cascade_attention_paged`` on pool + table;
* sliding-window ROLLING local layers — the DENSE cascade kernel over
  the rolling buffer with ``rolling=True`` and the TRUE capacity as
  position-recovery modulus (``models/blocks.py``);
* kv_seq-sharded paged reads (verify KV AND drafter feature caches) —
  the per-shard kernel inside ``shard_map``
  (``distributed/spdecode.sharded_paged_cache_attend``);
* still on gather: recurrent/rwkv blocks (no KV cache to kernelize),
  cross-attention, dense-cache engines under a kv_seq mesh, and
  GSPMD prefill.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import param as pm
from repro.models.layers import dense, apply_rope, softcap
from repro.distributed import compat
from repro.distributed.sharding import constrain

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


# ------------------------------------------------------------------ masks --
def make_attention_mask(tq: int, tkv: int, *, causal: bool, q_offset,
                        window: Optional[int] = None,
                        kv_len=None) -> jnp.ndarray:
    """Boolean mask (True = attend): [Tq,Tkv], or [B,Tq,Tkv] when
    ``q_offset``/``kv_len`` are per-example vectors.

    Query i has absolute position q_offset + i; key j has absolute position j.
    """
    q_off = jnp.asarray(q_offset)
    batched = q_off.ndim > 0 or (kv_len is not None
                                 and jnp.asarray(kv_len).ndim > 0)
    if batched:
        q_off = q_off.reshape(-1, 1, 1)
        qpos = jnp.arange(tq)[None, :, None] + q_off      # [B,Tq,1]
        kpos = jnp.arange(tkv)[None, None, :]
    else:
        qpos = jnp.arange(tq)[:, None] + q_off            # [Tq,1]
        kpos = jnp.arange(tkv)[None, :]
    shape = jnp.broadcast_shapes(qpos.shape, kpos.shape)
    mask = (kpos <= qpos) if causal else jnp.ones(shape, dtype=bool)
    if window is not None:
        mask &= kpos > (qpos - window)
    if kv_len is not None:
        kl = jnp.asarray(kv_len)
        if batched:
            kl = kl.reshape(-1, 1, 1)
        mask &= kpos < kl
    return mask


# ------------------------------------------------------------ dense impl --
def attend_dense(q, k, v, mask=None, *, scale=None, attn_softcap=None,
                 sinks=None):
    """q:[B,Tq,Hq,Dh] k,v:[B,Tkv,Hkv,Dh] mask:[B?,Tq,Tkv] or [B,Hq,Tq,Tkv]."""
    b, tq, hq, dh = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else dh ** -0.5
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qf = qf.reshape(b, tq, hkv, g, dh)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf)
    logits = softcap(logits, attn_softcap)
    if mask is not None:
        if mask.ndim == 2:
            mask = mask[None]
        m = mask[:, None, None]  # [B,1,1,Tq,Tkv]
        logits = jnp.where(m, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, vf)
    return out.reshape(b, tq, hq, dh).astype(q.dtype)


# ---------------------------------------------------------- chunked impl --
def attend_chunked(q, k, v, *, causal, q_offset, window=None, kv_len=None,
                   extra_mask=None, scale=None, attn_softcap=None,
                   kv_chunk: int = 1024, return_stats: bool = False,
                   key_offset=0, vary_axes=()):
    """Flash-style running softmax over KV chunks; never builds [Tq,Tkv].

    extra_mask: optional [Tq,Tkv] or [B,Tq,Tkv] bool, ANDed with causal etc.
    q_offset / kv_len: scalar or per-example [B].
    key_offset: absolute position of k[0] (cross-device KV sharding).
    return_stats: return (acc, m, l) un-normalized flash stats
        (acc [B,Hkv,G,Tq,Dh], m/l [B,Hkv,G,Tq]) for LSE merging.
    """
    b, tq, hq, dh = q.shape
    tkv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else dh ** -0.5
    kv_chunk = min(kv_chunk, tkv)
    n_chunks = (tkv + kv_chunk - 1) // kv_chunk
    pad = n_chunks * kv_chunk - tkv
    if extra_mask is not None and extra_mask.ndim == 2:
        extra_mask = extra_mask[None]
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if extra_mask is not None:
            extra_mask = jnp.pad(extra_mask, ((0, 0), (0, 0), (0, pad)))
    eff_kv_len = kv_len if kv_len is not None else tkv
    eff_kv_len = jnp.asarray(eff_kv_len)
    if eff_kv_len.ndim == 0:
        eff_kv_len = jnp.full((b,), eff_kv_len)

    qf = (q.astype(jnp.float32) * scale).reshape(b, tq, hkv, g, dh)
    kc = jnp.moveaxis(k.reshape(b, n_chunks, kv_chunk, hkv, dh), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, n_chunks, kv_chunk, hkv, dh), 1, 0)
    if extra_mask is not None:
        em = jnp.moveaxis(
            extra_mask.reshape(extra_mask.shape[0], tq, n_chunks, kv_chunk),
            2, 0)                                        # [C, B?, Tq, ck]
    else:
        em = None

    q_off = jnp.asarray(q_offset)
    if q_off.ndim == 0:
        q_off = jnp.full((b,), q_off)
    qpos = jnp.arange(tq)[None, :, None] + q_off[:, None, None]  # [B,Tq,1]

    def body(carry, inp):
        m_i, l_i, acc = carry
        if em is None:
            kcj, vcj, cidx = inp
            emj = None
        else:
            kcj, vcj, cidx, emj = inp
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kcj.astype(jnp.float32))
        logits = softcap(logits, attn_softcap)
        kpos = (key_offset + cidx * kv_chunk
                + jnp.arange(kv_chunk)[None, None, :])
        mask = jnp.ones((b, tq, kv_chunk), dtype=bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > (qpos - window)
        mask &= kpos < eff_kv_len[:, None, None]
        if emj is not None:
            mask &= emj
        logits = jnp.where(mask[:, None, None], logits, NEG_INF)
        m_new = jnp.maximum(m_i, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        alpha = jnp.exp(m_i - m_new)
        l_new = l_i * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vcj.astype(jnp.float32))
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, hkv, g, tq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, tq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, tq, dh), jnp.float32)
    if vary_axes:
        # inside shard_map with check_vma: scan carries must start with the
        # same varying-manual-axes type as the loop-carried updates
        m0 = compat.pvary(m0, tuple(vary_axes))
        l0 = compat.pvary(l0, tuple(vary_axes))
        a0 = compat.pvary(a0, tuple(vary_axes))
    xs = (kc, vc, jnp.arange(n_chunks)) if em is None else (
        kc, vc, jnp.arange(n_chunks), em)
    (m_f, l_f, acc), _ = jax.lax.scan(body, (m0, l0, a0), xs)
    if return_stats:
        return acc, m_f, l_f
    out = acc / jnp.maximum(l_f, 1e-30)[..., None]
    out = jnp.moveaxis(out, 3, 1)  # [B,Tq,Hkv,g,Dh]
    return out.reshape(b, tq, hq, dh).astype(q.dtype)


def merge_attn_stats(parts, q_shape, dtype):
    """Merge flash partials [(acc, m, l), ...] by log-sum-exp -> [B,Tq,Hq,Dh].
    """
    b, tq, hq, dh = q_shape
    m_g = parts[0][1]
    for _, m, _ in parts[1:]:
        m_g = jnp.maximum(m_g, m)
    l_g = sum(l * jnp.exp(m - m_g) for _, m, l in parts)
    acc_g = sum(acc * jnp.exp(m - m_g)[..., None] for acc, m, _ in parts)
    out = acc_g / jnp.maximum(l_g, 1e-30)[..., None]
    out = jnp.moveaxis(out, 3, 1)
    return out.reshape(b, tq, hq, dh).astype(dtype)


def attend_cache_plus_block(q, kk, vv, *, cache_cap, cache_len, q_abs,
                            window, extra_mask, attn_softcap, impl,
                            kv_chunk, rolling):
    """Single-softmax attention over [cache(cap) ++ block(T)] — the
    decode/verify read path shared by every attention block.

    ``kk``/``vv``: the cache's *logical view* concatenated with the
    in-flight block's K/V. For a dense cache the logical view is the
    buffer itself; for a paged cache it is :func:`repro.models.kvcache.
    pool_view` (page-table-ordered gather), which holds identical values
    at every committed position, so both layouts produce bit-identical
    attention (garbage beyond ``cache_len`` is masked the same way).

    ``q_abs``: [Tq] or [B,Tq] absolute position of each query token (tree
    nodes carry depth-based positions). ``cache_len``: scalar or [B]. Cache
    slot j of a non-rolling cache holds absolute position j; a rolling cache
    slot j holds the largest t<cache_len with t % cap == j. ``extra_mask``:
    [Tq,T_blk] or [B,Tq,T_blk] tree/bidir mask for the in-flight block tail
    (defaults to causal-in-block by block order).
    """
    b, tq = q.shape[:2]
    total = kk.shape[1]
    t_blk = total - cache_cap
    clen = jnp.asarray(cache_len)
    batched = (clen.ndim > 0) or (jnp.asarray(q_abs).ndim > 1) or (
        extra_mask is not None and extra_mask.ndim > 2)
    if batched:
        clen = jnp.broadcast_to(clen.reshape(-1, 1, 1), (b, 1, 1))
        qpos = jnp.broadcast_to(
            jnp.asarray(q_abs).reshape(-1, tq)[..., None], (b, tq, 1))
        jc = jnp.arange(cache_cap)[None, None, :]
    else:
        qpos = jnp.asarray(q_abs)[:, None]                  # [Tq,1]
        jc = jnp.arange(cache_cap)[None, :]
    if rolling:
        last = clen - 1
        abs_kpos = last - jnp.mod(last - jc, cache_cap)
        cache_ok = (abs_kpos >= 0) & (abs_kpos < clen) & (abs_kpos <= qpos)
        if window is not None:
            cache_ok &= abs_kpos > (qpos - window)
    else:
        cache_ok = (jc < clen) & (jc <= qpos)
        if window is not None:
            cache_ok &= jc > (qpos - window)
    tgt_shape = (b, tq, cache_cap) if batched else (tq, cache_cap)
    cache_ok = jnp.broadcast_to(cache_ok, tgt_shape)
    if extra_mask is not None:
        blk = extra_mask
        if batched and blk.ndim == 2:
            blk = jnp.broadcast_to(blk[None], (b, tq, t_blk))
    else:
        blk = jnp.tril(jnp.ones((tq, t_blk), dtype=bool), k=t_blk - tq)
        if window is not None:
            ji = jnp.arange(t_blk)[None, :]
            ii = jnp.arange(tq)[:, None] + (t_blk - tq)
            blk = blk & (ji > (ii - window))
        if batched:
            blk = jnp.broadcast_to(blk[None], (b, tq, t_blk))
    full_mask = jnp.concatenate([cache_ok, blk], axis=-1)
    return attend(q, kk, vv, causal=False, q_offset=0, extra_mask=full_mask,
                  attn_softcap=attn_softcap, impl=impl, kv_chunk=kv_chunk)


def attend(q, k, v, *, causal=True, q_offset=0, window=None, kv_len=None,
           extra_mask=None, scale=None, attn_softcap=None, impl="auto",
           kv_chunk=1024):
    """Unified attention entry point."""
    tq, tkv = q.shape[1], k.shape[1]
    if impl == "auto":
        impl = "dense" if (tq * tkv <= 256 * 1024) else "chunked"
    if impl == "dense":
        mask = make_attention_mask(tq, tkv, causal=causal, q_offset=q_offset,
                                   window=window, kv_len=kv_len)
        if extra_mask is not None:
            mask = mask & extra_mask
        return attend_dense(q, k, v, mask, scale=scale,
                            attn_softcap=attn_softcap)
    if impl == "chunked":
        return attend_chunked(q, k, v, causal=causal, q_offset=q_offset,
                              window=window, kv_len=kv_len,
                              extra_mask=extra_mask, scale=scale,
                              attn_softcap=attn_softcap, kv_chunk=kv_chunk)
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.flash_attention(
            q, k, v, causal=causal, q_offset=q_offset, window=window,
            kv_len=kv_len, scale=scale, attn_softcap=attn_softcap)
    raise ValueError(f"unknown attention impl {impl!r}")


# ------------------------------------------------------------- module -----
def attn_init(key, cfg, cross: bool = False):
    """QKV/O projections. Fused layouts: wq [d, Hq*Dh], wk/wv [d, Hkv*Dh]."""
    d, hq, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = pm.split(key, 4)
    p = {
        "wq": pm.dense_init(ks[0], d, hq * dh),
        "wk": pm.dense_init(ks[1], d, hkv * dh),
        "wv": pm.dense_init(ks[2], d, hkv * dh),
        "wo": pm.dense_init(ks[3], hq * dh, d, scale=(hq * dh) ** -0.5),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = pm.zeros((hq * dh,))
        p["bk"] = pm.zeros((hkv * dh,))
        p["bv"] = pm.zeros((hkv * dh,))
    if cfg.qk_norm:
        p["q_norm"] = pm.ones((dh,))
        p["k_norm"] = pm.ones((dh,))
    return p


def project_qkv(p, x, cfg, positions=None, rope: bool = True):
    """x:[B,T,d] -> q:[B,T,Hq,Dh], k,v:[B,T,Hkv,Dh] (+rope, +qknorm)."""
    b, t, _ = x.shape
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = dense(p["wq"], x, p.get("bq")).reshape(b, t, hq, dh)
    k = dense(p["wk"], x, p.get("bk")).reshape(b, t, hkv, dh)
    v = dense(p["wv"], x, p.get("bv")).reshape(b, t, hkv, dh)
    if cfg.qk_norm:
        q = _rms_head(q, p["q_norm"], cfg.norm_eps)
        k = _rms_head(k, p["k_norm"], cfg.norm_eps)
    if rope and positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, ("batch", None, "heads", None))
    k = constrain(k, ("batch", None, "kv_heads", None))
    v = constrain(v, ("batch", None, "kv_heads", None))
    return q, k, v


def _rms_head(x, scale, eps):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(dt)


def out_proj(p, attn_out):
    b, t, hq, dh = attn_out.shape
    y = dense(p["wo"], attn_out.reshape(b, t, hq * dh))
    return y
