"""Encoder-decoder composition (Whisper-style).

Encoder: bidirectional transformer over precomputed frame embeddings (the
conv frontend is a stub per the assignment — ``input_specs`` provides frame
embeddings directly). Decoder: the standard LM stack with cross-attention to
the encoder output (cfg.cross_attn_every=1).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models import param as pm
from repro.models.attention import attend, attn_init, out_proj, project_qkv
from repro.models.layers import rmsnorm, rmsnorm_init
from repro.models.mlp import mlp, mlp_init
from repro.models import lm
from repro.distributed.sharding import constrain


def encoder_init(key, cfg: ModelConfig):
    ks = pm.split(key, cfg.enc_num_layers + 1)
    p: Dict[str, Any] = {"ln_f": rmsnorm_init(cfg.d_model)}
    layers = []
    for i in range(cfg.enc_num_layers):
        kk = pm.split(ks[i], 2)
        layers.append({
            "ln1": rmsnorm_init(cfg.d_model),
            "attn": attn_init(kk[0], cfg),
            "ln2": rmsnorm_init(cfg.d_model),
            "mlp": mlp_init(kk[1], cfg.d_model, cfg.d_ff, cfg.mlp_gated),
        })
    # stack for scan
    p["layers"] = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    return p


def encode(p, feats, cfg: ModelConfig, attn_impl: str = "auto"):
    """feats: [B, T_enc, d] (stub frontend output) -> [B, T_enc, d]."""
    x = feats.astype(jnp.dtype(cfg.dtype))
    x = constrain(x, ("batch", "act_seq", "embed"))
    b, t, _ = x.shape
    positions = jnp.arange(t)[None, :]

    def body(x, lp):
        h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
        q, k, v = project_qkv(lp["attn"], h, cfg, positions=positions)
        y = attend(q, k, v, causal=False, impl=attn_impl)
        x = x + out_proj(lp["attn"], y)
        h = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        x = x + mlp(lp["mlp"], h, cfg.mlp_act, cfg.mlp_gated)
        x = constrain(x, ("batch", "act_seq", "embed"))
        return x, None

    x, _ = jax.lax.scan(body, x, p["layers"])
    return rmsnorm(p["ln_f"], x, cfg.norm_eps)


def encdec_init(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    return {"encoder": encoder_init(k1, cfg), "decoder": lm.lm_init(k2, cfg)}


def encdec_loss(params, batch, cfg: ModelConfig, **kw):
    """batch: dict(tokens, labels, mask, audio_feats [B,T_enc,d])."""
    enc = encode(params["encoder"], batch["audio_feats"], cfg,
                 attn_impl=kw.get("attn_impl", "auto"))
    return lm.loss_fn(params["decoder"], batch, cfg, ctx=enc, **kw)
