"""Primitive layers: norms, dense projections, embeddings, RoPE, softcap."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import param as pm


# ---------------------------------------------------------------- norms ----
def rmsnorm_init(d):
    return {"scale": pm.ones((d,))}


def rmsnorm(p, x, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * p["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(d):
    return {"scale": pm.ones((d,)), "bias": pm.zeros((d,))}


def layernorm(p, x, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(dt)


# ---------------------------------------------------------------- dense ----
def dense(w, x, bias=None):
    y = jnp.einsum("...i,io->...o", x, w.astype(x.dtype))
    if bias is not None:
        y = y + bias.astype(x.dtype)
    return y


# ----------------------------------------------------------------- rope ----
def rope_freqs(head_dim, theta):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta=10000.0):
    """x: [..., T, H, Dh]; positions: [..., T] int32."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                              # [Dh/2]
    ang = positions[..., None].astype(jnp.float32) * freqs     # [..., T, Dh/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]                                    # [..., T, 1, Dh/2]
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------------- softcap ----
def softcap(x, cap):
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# ----------------------------------------------------------- activation ----
def act_fn(name):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu, "relu2": lambda x: jnp.square(jax.nn.relu(x)),
            }[name]


# ------------------------------------------------------------ embedding ----
def embedding_init(key, vocab, d, dtype=jnp.float32):
    return {"embedding": pm.trunc_normal(key, (vocab, d), dtype, stddev=0.02)}


def embed(p, tokens, dtype):
    return p["embedding"].astype(dtype)[tokens]


def unembed(w, x):
    """lm head: x [..., d] @ w [d, vocab]."""
    return jnp.einsum("...d,dv->...v", x, w.astype(x.dtype))
