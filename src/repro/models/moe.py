"""Mixture-of-Experts FFN with expert parallelism.

Dispatch paths (same math; allclose-tested against each other):

* ``einsum``     — GShard-style one-hot dispatch (tiny configs, oracle).
* ``scatter``    — capacity-bucket scatter/gather; expert tensors are laid
                   out ``[E, C, d]`` and sharded over the ``experts`` logical
                   axis, so under SPMD the dispatch lowers to all-to-all-like
                   collectives. Default for production meshes.

Routing: top-k softmax over selected experts (renormalized), capacity
``C = ceil(T*k/E * capacity_factor)``; overflow tokens drop that expert's
contribution (standard GShard behaviour). Shared experts (DeepSeek-style)
bypass routing.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import param as pm
from repro.models.layers import act_fn
from repro.distributed.sharding import constrain


def moe_init(key, cfg):
    m = cfg.moe
    d, dff, e = cfg.d_model, cfg.d_ff, m.num_experts
    ks = pm.split(key, 5)

    def stack(k2, din, dout, scale=None):
        kk = pm.split(k2, e)
        return jnp.stack([pm.dense_init(kk[i], din, dout, scale=scale)
                          for i in range(e)])

    p = {
        "router": pm.dense_init(ks[0], d, e, scale=0.02),
        "moe_w_in": stack(ks[1], d, dff),
        "moe_w_out": stack(ks[2], dff, d, scale=dff ** -0.5),
    }
    if cfg.mlp_gated:
        p["moe_w_gate"] = stack(ks[3], d, dff)
    if m.num_shared_experts:
        from repro.models.mlp import mlp_init
        p["shared"] = mlp_init(ks[4], d, dff * m.num_shared_experts,
                               cfg.mlp_gated)
    return p


def _route(p, x2, cfg):
    """x2: [T, d] -> (gates [T,k], idx [T,k])."""
    m = cfg.moe
    logits = jnp.einsum("td,de->te", x2.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    gates, idx = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), m.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, idx


def _capacity(t, cfg):
    m = cfg.moe
    c = int(math.ceil(t * m.top_k / m.num_experts * m.capacity_factor))
    return max(c, min(8, t))


def _expert_ffn(p, xin, cfg):
    """xin: [E, C, d] -> [E, C, d], batched expert matmuls."""
    h = jnp.einsum("ecd,edf->ecf", xin, p["moe_w_in"].astype(xin.dtype))
    if cfg.mlp_gated:
        g = jnp.einsum("ecd,edf->ecf", xin, p["moe_w_gate"].astype(xin.dtype))
        h = act_fn(cfg.mlp_act)(g) * h
    else:
        h = act_fn(cfg.mlp_act)(h)
    h = constrain(h, ("experts", None, "expert_ffn"))
    return jnp.einsum("ecf,efd->ecd", h, p["moe_w_out"].astype(xin.dtype))


def moe_apply(p, x, cfg, dispatch: Optional[str] = None):
    """x: [B,T,d] -> [B,T,d].

    Under an active mesh with an experts axis, the shard_map EP path is used
    (replicated-token expert parallelism — one activation psum per layer;
    see distributed/ep.py and §Perf): it replaces both pjit dispatch paths,
    which gather expert weights under SPMD.
    """
    m = cfg.moe
    dispatch = dispatch or m.dispatch
    b, t, d = x.shape
    if dispatch != "einsum":
        from repro.distributed.ep import ep_available, moe_apply_ep
        if ep_available(cfg):
            y = moe_apply_ep(p, x, cfg)
            if m.num_shared_experts:
                from repro.models.mlp import mlp
                y = y + mlp(p["shared"], x, cfg.mlp_act, cfg.mlp_gated
                            ).astype(y.dtype)
            return y.astype(x.dtype)
    x2 = x.reshape(b * t, d)
    gates, idx = _route(p, x2, cfg)
    cap = _capacity(b * t, cfg)

    if dispatch == "einsum":
        y2 = _apply_einsum(p, x2, gates, idx, cap, cfg)
    elif dispatch in ("scatter", "all_to_all"):
        y2 = _apply_scatter(p, x2, gates, idx, cap, cfg)
    else:
        raise ValueError(dispatch)

    if m.num_shared_experts:
        from repro.models.mlp import mlp
        y2 = y2 + mlp(p["shared"], x2[None], cfg.mlp_act,
                      cfg.mlp_gated)[0].astype(y2.dtype)
    return y2.reshape(b, t, d).astype(x.dtype)


def _positions(idx, e, cap):
    """Rank of each (token, choice) within its expert's queue. [T,k]."""
    tk = idx.shape[0] * idx.shape[1]
    flat = idx.reshape(-1)                               # [T*k], row-major:
    onehot = jax.nn.one_hot(flat, e, dtype=jnp.int32)    # priority = token order
    pos = jnp.cumsum(onehot, axis=0) - 1                 # [T*k, E]
    pos = jnp.take_along_axis(pos, flat[:, None], axis=1)[:, 0]
    return pos.reshape(idx.shape)                        # [T,k]


def _apply_einsum(p, x2, gates, idx, cap, cfg):
    e = cfg.moe.num_experts
    t = x2.shape[0]
    pos = _positions(idx, e, cap)
    keep = pos < cap
    # one-hot dispatch/combine tensors [T, E, C]
    oh_e = jax.nn.one_hot(idx, e, dtype=x2.dtype)          # [T,k,E]
    oh_c = jax.nn.one_hot(jnp.where(keep, pos, cap), cap,
                          dtype=x2.dtype)                  # [T,k,C] (oob -> 0)
    disp = jnp.einsum("tke,tkc->tec", oh_e, oh_c)
    comb = jnp.einsum("tke,tkc,tk->tec", oh_e, oh_c, gates.astype(x2.dtype))
    xin = jnp.einsum("tec,td->ecd", disp, x2)
    xout = _expert_ffn(p, xin, cfg)
    return jnp.einsum("tec,ecd->td", comb, xout)


def _apply_scatter(p, x2, gates, idx, cap, cfg):
    e = cfg.moe.num_experts
    t, d = x2.shape
    k = idx.shape[1]
    pos = _positions(idx, e, cap)
    keep = (pos < cap).reshape(-1)
    ef = idx.reshape(-1)
    pf = jnp.where(keep, pos.reshape(-1), 0)
    src = jnp.repeat(jnp.arange(t), k)
    xin = jnp.zeros((e, cap, d), x2.dtype)
    vals = x2[src] * keep[:, None].astype(x2.dtype)
    xin = xin.at[ef, pf].add(vals, mode="drop")
    xin = constrain(xin, ("experts", None, None))
    xout = _expert_ffn(p, xin, cfg)
    xout = constrain(xout, ("experts", None, None))
    picked = xout[ef, pf] * keep[:, None].astype(x2.dtype)  # [T*k, d]
    w = gates.reshape(-1)[:, None].astype(x2.dtype)
    y2 = jnp.zeros_like(x2).at[src].add(picked * w)
    return y2
