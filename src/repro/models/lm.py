"""Causal LM: embedding -> scanned block stack -> final norm -> lm head.

One ``forward`` covers train / prefill / decode / tree-verify via arguments
(see blocks.py). Returns multi-layer features for DFlash drafter conditioning
and per-layer self-KV of the pass (``kv_outs``) so verification can commit
accepted KV without recompute (SpecInfer-style gather-commit).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models import kvcache
from repro.models import param as pm
from repro.models.blocks import (BlockSpec2, block_apply, block_init,
                                 block_state_init, period_spec)
from repro.models.layers import (embed, embedding_init, rmsnorm, rmsnorm_init,
                                 softcap, unembed)
from repro.distributed.sharding import constrain


# ------------------------------------------------------------------ init ---
def lm_init(key, cfg: ModelConfig):
    spec, n_periods, tail = period_spec(cfg)
    ks = pm.split(key, 4 + len(tail))
    p: Dict[str, Any] = {
        "tok": embedding_init(ks[0], cfg.vocab_size, cfg.d_model),
        "ln_f": rmsnorm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = pm.dense_init(ks[1], cfg.d_model, cfg.vocab_size,
                                     scale=0.02)
    if n_periods > 0:
        period_params = {}
        for j, bs in enumerate(spec):
            keys = jax.random.split(jax.random.fold_in(ks[2], j), n_periods)
            period_params[f"p{j}"] = jax.vmap(
                lambda k: block_init(k, cfg, bs))(keys)
        p["period"] = period_params
    for i, bs in enumerate(tail):
        p[f"tail{i}"] = block_init(ks[4 + i], cfg, bs)
    return p


def init_states(cfg: ModelConfig, batch: int, max_len: int, ctx_len: int = 0,
                dtype=jnp.bfloat16, cache_impl: str = "dense",
                page_size: int = 64, pool_pages: Optional[int] = None,
                page_table=None, ext_pools=None):
    """Allocate per-layer decode states.

    cache_impl="paged": global-attention KV lives in page pools shared
    across the batch; ``page_table`` [B, max_pages] maps each row's
    logical pages to physical pool pages (default: the identity layout,
    ``pool_pages = batch * ceil(max_len/page_size)``). The table is
    replicated into every paged block state (tiny int32) so the scanned
    stack threads it with no extra forward arguments.

    ext_pools: optional ``{state_key: (k_pool, v_pool)}`` of retained
    device pool buffers (``core.state.capture_pools`` of a previous
    wave). Named entries adopt the external buffers instead of allocating
    fresh zeroed pools — no transient pool-sized allocation at wave
    turnover. Stacked-period entries ("p{j}") expect the already-stacked
    ``[n_periods, P, page, H, D]`` buffers capture harvested.
    """
    spec, n_periods, tail = period_spec(cfg)
    if cache_impl == "paged":
        pool_pages, page_table = kvcache.default_page_layout(
            batch, max_len, page_size, pool_pages, page_table)
    ext_pools = ext_pools or {}
    assert not ext_pools or cache_impl == "paged", \
        "retained pool buffers require cache_impl='paged'"
    kw = dict(cache_impl=cache_impl, page_size=page_size,
              pool_pages=pool_pages or 0, page_table=page_table)
    states: Dict[str, Any] = {}
    if n_periods > 0:
        for j, bs in enumerate(spec):
            one = block_state_init(cfg, bs, batch, max_len, ctx_len, dtype,
                                   alloc_pool=f"p{j}" not in ext_pools, **kw)
            # None pool placeholders are empty pytree nodes: tree.map
            # skips them, so no zeroed pool is ever materialized here
            states[f"p{j}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n_periods,) + a.shape).copy()
                if n_periods > 1 else a[None], one)
    for i, bs in enumerate(tail):
        states[f"tail{i}"] = block_state_init(
            cfg, bs, batch, max_len, ctx_len, dtype,
            alloc_pool=f"tail{i}" not in ext_pools, **kw)
    for name, (k, v) in ext_pools.items():
        st = states.get(name)
        assert (isinstance(st, dict) and "pt" in st
                and st.get("k") is None), \
            f"ext pool {name!r} does not name a paged cache"
        assert k.shape[-4:] == (pool_pages, page_size,
                                cfg.num_kv_heads, cfg.head_dim) \
            and k.dtype == dtype, ("retained pool geometry mismatch",
                                   name, k.shape)
        st["k"], st["v"] = k, v
    states["length"] = jnp.zeros((batch,), jnp.int32)
    return states


def state_batch_axis(key: str) -> int:
    """Batch axis of a target-state leaf, by its dict key in the layout
    :func:`init_states` builds: stacked-period entries ("p0", "p1", ...)
    carry [n_periods, B, ...]; everything else ("tailN", "length") is
    batch-leading. Single source of truth for code that indexes or
    repeats state rows (EngineState.adopt_row, StateReplayVerifier)."""
    return 1 if key.startswith("p") else 0


# --------------------------------------------------------------- forward ---
def forward(params, tokens, cfg: ModelConfig, *, states=None, cache_len=None,
            positions=None, write_kv: bool = False, extra_mask=None,
            ctx=None, attn_impl: str = "auto", kv_chunk: int = 1024,
            want_features: bool = False, want_logits: bool = True,
            remat: Optional[bool] = None, inputs_embeds=None, snap_at=None,
            attend_cache_on_write: bool = False):
    """tokens: [B,T] int32 (or ``inputs_embeds`` [B,T,d]).

    snap_at: [B] — replay-commit mode: states advance by exactly snap_at
    tokens per example (recurrent snapshots + dropped KV writes).
    Returns dict(logits, states, features, kv_outs, hidden).
    """
    spec, n_periods, tail = period_spec(cfg)
    dtype = jnp.dtype(cfg.dtype)
    if inputs_embeds is None:
        x = embed(params["tok"], tokens, dtype)
    else:
        x = inputs_embeds.astype(dtype)
    x = constrain(x, ("batch", "act_seq", "embed"))
    b, t = x.shape[:2]
    if states is not None and cache_len is None:
        cache_len = states["length"]
    if cache_len is None:
        cache_len = jnp.zeros((), jnp.int32)
    if positions is None:
        cl = jnp.asarray(cache_len)
        ar = jnp.arange(t, dtype=jnp.int32)
        positions = cl[:, None] + ar[None, :] if cl.ndim else cl + ar
    remat = cfg.remat if remat is None else remat

    def run_period(x, period_params, period_state):
        new_state = {}
        kv_outs = {}
        for j, bs in enumerate(spec):
            st = period_state.get(f"p{j}") if period_state else None
            x, ns, kv = block_apply(
                period_params[f"p{j}"], x, cfg, bs, state=st,
                cache_len=cache_len, positions=positions, write_kv=write_kv,
                extra_mask=extra_mask, ctx=ctx, attn_impl=attn_impl,
                kv_chunk=kv_chunk, snap_at=snap_at,
                attend_cache_on_write=attend_cache_on_write)
            if ns is not None:
                new_state[f"p{j}"] = ns
            kv_outs[f"p{j}"] = kv
        return x, new_state, kv_outs

    if remat:
        run_period = jax.checkpoint(
            run_period,
            policy=(jax.checkpoint_policies.checkpoint_dots
                    if cfg.remat_policy == "dots" else None))

    hiddens = []
    all_kv = {}
    new_states: Dict[str, Any] = {}

    if n_periods > 0:
        pparams = params["period"]
        pstates = ({k: states[k] for k in pparams} if states is not None
                   else None)

        def body(x, xs):
            pp, ps = xs
            x, ns, kv = run_period(x, pp, ps)
            return x, (ns, kv, x)

        if states is None:
            def body_nostate(x, pp):
                x, ns, kv = run_period(x, pp, None)
                return x, (kv, x)

            x, (kv_y, hid_y) = jax.lax.scan(body_nostate, x, pparams)
        else:
            x, (ns_y, kv_y, hid_y) = jax.lax.scan(body, x, (pparams, pstates))
            new_states.update(ns_y)
        all_kv["period"] = kv_y
        hiddens.append(hid_y)     # [n_periods, B, T, d]

    for i, bs in enumerate(tail):
        st = states.get(f"tail{i}") if states is not None else None
        x, ns, kv = block_apply(
            params[f"tail{i}"], x, cfg, bs, state=st, cache_len=cache_len,
            positions=positions, write_kv=write_kv, extra_mask=extra_mask,
            ctx=ctx, attn_impl=attn_impl, kv_chunk=kv_chunk, snap_at=snap_at,
                attend_cache_on_write=attend_cache_on_write)
        if ns is not None:
            new_states[f"tail{i}"] = ns
        all_kv[f"tail{i}"] = kv
        hiddens.append(x[None])

    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)

    features = None
    if want_features:
        stacked = jnp.concatenate(hiddens, axis=0)   # [L', B, T, d]
        m = min(cfg_feature_layers(cfg), stacked.shape[0])
        feats = stacked[-m:]
        features = jnp.moveaxis(feats, 0, 2).reshape(b, t, m * cfg.d_model)

    logits = None
    if want_logits:
        head = (params["tok"]["embedding"].T if cfg.tie_embeddings
                else params["lm_head"])
        logits = unembed(head, x)
        logits = softcap(logits, cfg.logit_softcap)
        logits = constrain(logits, ("batch", None, "vocab"))

    if states is not None:
        out_states = dict(states)
        out_states.update(new_states)
        if write_kv:
            new_len = cache_len + (t if snap_at is None else snap_at)
            out_states["length"] = jnp.broadcast_to(
                new_len, states["length"].shape).astype(jnp.int32)
        result_states = out_states
    else:
        result_states = None

    return {"logits": logits, "states": result_states, "features": features,
            "kv_outs": all_kv, "hidden": x}


def cfg_feature_layers(cfg) -> int:
    return 3


def feature_dim(cfg: ModelConfig) -> int:
    """Width of the drafter-conditioning features ``forward`` emits:
    min(3, available period/tail hiddens) * d_model."""
    _, n_periods, tail = period_spec(cfg)
    avail = (n_periods if n_periods > 0 else 0) + len(tail)
    return min(cfg_feature_layers(cfg), max(avail, 1)) * cfg.d_model


# ------------------------------------------------------------ loss/train ---
def cross_entropy(logits, labels, mask=None, z_loss: float = 1e-4):
    """logits [B,T,V] (any float), labels [B,T] int32."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    if mask is not None:
        nll = nll * mask
        denom = jnp.maximum(mask.sum(), 1.0)
    else:
        denom = nll.size
    return nll.sum() / denom


def loss_fn(params, batch, cfg: ModelConfig, *, attn_impl="auto",
            kv_chunk=1024, loss_seq_chunk: Optional[int] = None, ctx=None):
    """batch: dict(tokens [B,S], labels [B,S], mask [B,S])."""
    out = forward(params, batch["tokens"], cfg, attn_impl=attn_impl,
                  kv_chunk=kv_chunk, ctx=ctx,
                  want_logits=loss_seq_chunk is None)
    if loss_seq_chunk is None:
        return cross_entropy(out["logits"], batch["labels"],
                             batch.get("mask"))
    # chunked CE over sequence: never materialize [B,S,V] logits
    h = out["hidden"]
    head = (params["tok"]["embedding"].T if cfg.tie_embeddings
            else params["lm_head"])
    b, s, d = h.shape
    c = loss_seq_chunk
    assert s % c == 0
    hc = h.reshape(b, s // c, c, d).swapaxes(0, 1)
    lc = batch["labels"].reshape(b, s // c, c).swapaxes(0, 1)
    mc = (batch["mask"].reshape(b, s // c, c).swapaxes(0, 1)
          if batch.get("mask") is not None else
          jnp.ones((s // c, b, c), jnp.float32))

    def chunk_loss(carry, inp):
        hj, lj, mj = inp
        logits = softcap(unembed(head, hj), cfg.logit_softcap)
        lf = logits.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(lf, axis=-1)
        ll = jnp.take_along_axis(lf, lj[..., None], axis=-1)[..., 0]
        nll = (lse - ll + 1e-4 * jnp.square(lse)) * mj
        return (carry[0] + nll.sum(), carry[1] + mj.sum()), None

    (tot, cnt), _ = jax.lax.scan(
        jax.checkpoint(chunk_loss), (jnp.zeros(()), jnp.zeros(())),
        (hc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)


# -------------------------------------------------------------- KV commit --
def commit_kv(states, kv_outs, cfg: ModelConfig, path_idx, n_commit):
    """Write the accepted path's KV into the caches (per-example ragged).

    kv_outs: pytree from ``forward`` over T_tree tokens.
    path_idx: [B, P] int32 — per-example tree-node indices of the best path
        (P = max_depth+1 including the anchor at entry 0).
    n_commit: [B] int32 — tokens to commit per example (anchor + accepted =
        n_acc + 1). Entries beyond n_commit are NOT written (dropped), so
        rolling caches stay intact.
    """
    spec, n_periods, tail = period_spec(cfg)
    length = states["length"]                      # [B] (or scalar)
    length = jnp.asarray(length)
    b, p = path_idx.shape
    if length.ndim == 0:
        length = jnp.broadcast_to(length, (b,))
    valid = jnp.arange(p)[None, :] < n_commit[:, None]        # [B, P]
    new_states = dict(states)

    def write(state, kv, rolling):
        if kv is None:
            return state
        k, v = kv                                  # [(n,) B, T_tree, H, D]
        st = dict(state)
        paged = kvcache.is_paged(st)
        stacked = k.ndim == 5
        tree_ax = 2 if stacked else 1
        idx_g = path_idx
        if stacked:
            idx_g = jnp.broadcast_to(path_idx[None], (k.shape[0], b, p))
        k_path = jnp.take_along_axis(
            k, idx_g[..., None, None], axis=tree_ax)
        v_path = jnp.take_along_axis(
            v, idx_g[..., None, None], axis=tree_ax)
        # write positions: per-example length + 0..P-1 (mod cap if rolling);
        # invalid entries pushed out of bounds -> dropped by scatter
        wpos = length[:, None] + jnp.arange(p)[None, :]
        if paged:
            # page-wise commit: only the tail page(s) covering
            # [length, length+n_commit) are written; the page table is
            # untouched (allocation is fixed for the request's lifetime,
            # so masked rows trivially freeze their tables)
            st["k"] = kvcache.pool_scatter(st["k"], st["pt"], k_path, wpos,
                                           valid=valid)
            st["v"] = kvcache.pool_scatter(st["v"], st["pt"], v_path, wpos,
                                           valid=valid)
            return st
        cap = st["k"].shape[-3]
        if rolling:
            wpos = jnp.mod(wpos, cap)
        wpos = jnp.where(valid, wpos, cap + 1)
        bidx = jnp.broadcast_to(jnp.arange(b)[:, None], (b, p))
        if stacked:
            st["k"] = st["k"].at[:, bidx, wpos].set(
                k_path.astype(st["k"].dtype), mode="drop")
            st["v"] = st["v"].at[:, bidx, wpos].set(
                v_path.astype(st["v"].dtype), mode="drop")
        else:
            st["k"] = st["k"].at[bidx, wpos].set(
                k_path.astype(st["k"].dtype), mode="drop")
            st["v"] = st["v"].at[bidx, wpos].set(
                v_path.astype(st["v"].dtype), mode="drop")
        return st

    if n_periods > 0:
        kv_y = kv_outs.get("period", {})
        for j, bs in enumerate(spec):
            if bs.kind in ("global", "local") and kv_y.get(f"p{j}") is not None:
                new_states[f"p{j}"] = write(states[f"p{j}"], kv_y[f"p{j}"],
                                            rolling=(bs.kind == "local"))
    for i, bs in enumerate(tail):
        kv = kv_outs.get(f"tail{i}")
        if bs.kind in ("global", "local") and kv is not None:
            new_states[f"tail{i}"] = write(states[f"tail{i}"], kv,
                                           rolling=(bs.kind == "local"))
    new_states["length"] = length + n_commit
    return new_states
