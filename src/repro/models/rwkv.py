"""RWKV-6 "Finch" blocks (arXiv:2404.05892): data-dependent decay time-mix
and squared-ReLU channel-mix.

Time-mix per head (state S in R^{Dh x Dh}):
    o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
with data-dependent decay w_t = exp(-exp(w0 + tanh(x_t A) B)) in (0,1).
Token shift mixes x_t with x_{t-1} via learned interpolation.

Two evaluation paths (allclose-tested against each other):
  * ``scan``    — lax.scan over time (decode; exact reference)
  * ``chunked`` — parallel intra-chunk + sequential inter-chunk state pass
                  (training; O(T/C) sequential steps) [flash-linear-attention
                  style, adapted to TPU matmul shapes]
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import param as pm
from repro.models.layers import dense

TM_STATE_KEYS = ("tm_x_prev", "tm_s")
CM_STATE_KEYS = ("cm_x_prev",)
_LORA = 64


def _heads(cfg):
    dh = cfg.rwkv_head_dim
    h = cfg.d_model // dh
    return h, dh


def time_mix_init(key, cfg):
    d = cfg.d_model
    h, dh = _heads(cfg)
    ks = pm.split(key, 9)
    return {
        "mu_r": pm.zeros((d,)) + 0.5, "mu_k": pm.zeros((d,)) + 0.5,
        "mu_v": pm.zeros((d,)) + 0.5, "mu_w": pm.zeros((d,)) + 0.5,
        "mu_g": pm.zeros((d,)) + 0.5,
        "wr": pm.dense_init(ks[0], d, h * dh),
        "wk": pm.dense_init(ks[1], d, h * dh),
        "wv": pm.dense_init(ks[2], d, h * dh),
        "wg": pm.dense_init(ks[3], d, h * dh),
        "wo": pm.dense_init(ks[4], h * dh, d, scale=(h * dh) ** -0.5),
        "w0": pm.zeros((h * dh,)) - 0.5,
        "w_lora_a": pm.dense_init(ks[5], d, _LORA),
        "w_lora_b": pm.dense_init(ks[6], _LORA, h * dh, scale=0.01),
        "u": pm.trunc_normal(ks[7], (h, dh), stddev=0.5),
        "ln_x": pm.ones((h * dh,)),
    }


def rwkv_state_init(cfg, batch: int, dtype=jnp.float32) -> Dict:
    d = cfg.d_model
    h, dh = _heads(cfg)
    return {
        "tm_x_prev": jnp.zeros((batch, d), dtype),
        "tm_s": jnp.zeros((batch, h, dh, dh), dtype),
        "cm_x_prev": jnp.zeros((batch, d), dtype),
    }


def _token_shift(x, x_prev, mu):
    """lerp(x_t, x_{t-1}); x: [B,T,d], x_prev: [B,d]."""
    prev = jnp.concatenate([x_prev[:, None].astype(x.dtype), x[:, :-1]], axis=1)
    return x + (prev - x) * mu.astype(x.dtype)


def time_mix(p, x, cfg, state: Optional[Dict] = None, chunk: int = 64,
             snap_at=None, impl: str = "scan"):
    b, t, d = x.shape
    h, dh = _heads(cfg)
    st = state or {k: v for k, v in rwkv_state_init(cfg, b).items()
                   if k in TM_STATE_KEYS or k == "tm_s"}
    xr = _token_shift(x, st["tm_x_prev"], p["mu_r"])
    xk = _token_shift(x, st["tm_x_prev"], p["mu_k"])
    xv = _token_shift(x, st["tm_x_prev"], p["mu_v"])
    xw = _token_shift(x, st["tm_x_prev"], p["mu_w"])
    xg = _token_shift(x, st["tm_x_prev"], p["mu_g"])
    r = dense(p["wr"], xr).reshape(b, t, h, dh).astype(jnp.float32)
    k = dense(p["wk"], xk).reshape(b, t, h, dh).astype(jnp.float32)
    v = dense(p["wv"], xv).reshape(b, t, h, dh).astype(jnp.float32)
    g = jax.nn.silu(dense(p["wg"], xg)).astype(jnp.float32)
    # data-dependent decay in (0,1)
    ww = p["w0"] + dense(p["w_lora_b"], jnp.tanh(dense(p["w_lora_a"], xw)))
    w = jnp.exp(-jnp.exp(ww.astype(jnp.float32))).reshape(b, t, h, dh)
    u = p["u"].astype(jnp.float32)

    s0 = st["tm_s"].astype(jnp.float32)
    if t == 1 and snap_at is None:
        kt, vt, rt, wt = k[:, 0], v[:, 0], r[:, 0], w[:, 0]
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        o = jnp.einsum("bhk,bhkv->bhv", rt, s0 + u[None, :, :, None] * kv)
        s = w[:, 0][..., None] * s0 + kv
        out = o[:, None]                                     # [B,1,H,Dh]
    elif impl == "chunked" and snap_at is None and t % min(chunk, t) == 0:
        out, s = time_mix_chunked(r, k, v, w, u, s0, chunk=chunk)
    else:
        out, s = _time_mix_scan(r, k, v, w, u, s0, snap_at=snap_at)

    out = out.reshape(b, t, h * dh)
    # per-head group norm
    out = out.reshape(b, t, h, dh)
    mu_ = out.mean(-1, keepdims=True)
    var = out.var(-1, keepdims=True)
    out = (out - mu_) * jax.lax.rsqrt(var + 1e-5)
    out = out.reshape(b, t, h * dh) * p["ln_x"].astype(jnp.float32)
    y = dense(p["wo"], (out * g).astype(x.dtype))
    if snap_at is None:
        x_prev = x[:, -1]
    else:
        x_prev = jnp.take_along_axis(
            x, jnp.clip(snap_at - 1, 0, t - 1)[:, None, None], axis=1)[:, 0]
    new_state = {"tm_x_prev": x_prev.astype(jnp.float32), "tm_s": s}
    return y, new_state


def _time_mix_scan(r, k, v, w, u, s0, snap_at=None):
    """Sequential reference: scan over time. All inputs fp32.

    snap_at: optional [B] — final state reflects exactly snap_at tokens
    (O(1) extra memory: a conditional snapshot carried through the scan).
    """
    def step(carry, inp):
        s, snap, i = carry
        rt, kt, vt, wt = inp
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        o = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        s = wt[..., None] * s + kv
        if snap_at is not None:
            take = (i + 1) <= snap_at                      # [B]
            snap = jnp.where(take[:, None, None, None], s, snap)
        return (s, snap, i + 1), o

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, w))
    (s, snap, _), os_ = jax.lax.scan(
        step, (s0, s0, jnp.zeros((), jnp.int32)), xs)
    out = jnp.moveaxis(os_, 0, 1)
    return out, (snap if snap_at is not None else s)


def time_mix_chunked(r, k, v, w, u, s0, chunk: int = 64):
    """Chunked-parallel WKV: intra-chunk attention-like matmuls + inter-chunk
    state recurrence. Exactly equals the scan path (fp32).

    Shapes: r,k,v,w [B,T,H,Dh]; u [H,Dh]; s0 [B,H,Dh,Dh].
    """
    b, t, h, dh = r.shape
    c = min(chunk, t)
    assert t % c == 0, "pad T to chunk multiple"
    n = t // c
    rc = r.reshape(b, n, c, h, dh)
    kc = k.reshape(b, n, c, h, dh)
    vc = v.reshape(b, n, c, h, dh)
    wc = w.reshape(b, n, c, h, dh)
    logw = jnp.log(jnp.maximum(wc, 1e-38))
    # cumulative decay within chunk: P_i = prod_{j<=i} w_j (inclusive),
    # P^ex_i = prod_{j<i} w_j (exclusive).
    cuml = jnp.cumsum(logw, axis=2)                  # log P (inclusive)
    cuml_ex = cuml - logw                            # log P^ex
    tot = cuml[:, :, -1:]                            # log prod over chunk

    # o_i = r_i^T [ P^ex_i . S_in + sum_{j<i} (P^ex_i / P_j) k_j v_j^T
    #               + diag(u) k_i v_i^T ]
    # Factor the pairwise decay P^ex_i / P_j into query/key scalings (the
    # flash-linear-attention factorization). VALIDITY: the factored
    # exponents live in fp32, so the cumulative within-chunk decay must stay
    # within ~|35| nats or the k-side scaling overflows. With the RWKV6
    # parameterization w = exp(-exp(.)) and chunk<=32 this holds for all
    # realistic (trained) decays; the sequential scan path is the exact
    # reference for anything more extreme (and is the default impl).
    clamp = 35.0
    rq = rc * jnp.exp(jnp.maximum(cuml_ex, -clamp))  # r_i * P^ex_i  (<= 1)
    kq = kc * jnp.exp(-jnp.maximum(cuml, -clamp))    # k_j / P_j    (<= e^35)
    att = jnp.einsum("bnchd,bnkhd->bnhck", rq, kq)   # scores (strictly lower)
    tri = jnp.tril(jnp.ones((c, c), bool), -1)
    att = att * tri[None, None, None]
    intra = jnp.einsum("bnhck,bnkhd->bnchd", att, vc)
    bonus = jnp.einsum("bnchd,hd,bnchd->bnch", rc, u, kc)
    intra = intra + bonus[..., None] * vc

    # inter-chunk: carry state S across chunks
    kv_chunk = jnp.einsum("bnchd,bnche->bnhde",
                          kc * jnp.exp(tot - cuml), vc)  # decayed to chunk end
    decay_chunk = jnp.exp(tot[:, :, 0])              # [B,n,h,dh]

    def step(s, inp):
        kvn, dec, r_pe = inp
        o = jnp.einsum("bchd,bhde->bche", r_pe, s)
        s = dec[..., None] * s + kvn
        return s, o

    xs = (jnp.moveaxis(kv_chunk, 1, 0), jnp.moveaxis(decay_chunk, 1, 0),
          jnp.moveaxis(rq, 1, 0))
    s_fin, inter = jax.lax.scan(step, s0, xs)
    inter = jnp.moveaxis(inter, 0, 1)
    out = (intra + inter).reshape(b, t, h, dh)
    return out, s_fin


def channel_mix_init(key, cfg):
    d, dff = cfg.d_model, cfg.d_ff
    ks = pm.split(key, 3)
    return {
        "mu_k": pm.zeros((d,)) + 0.5, "mu_r": pm.zeros((d,)) + 0.5,
        "wk": pm.dense_init(ks[0], d, dff),
        "wv": pm.dense_init(ks[1], dff, d, scale=dff ** -0.5),
        "wr": pm.dense_init(ks[2], d, d),
    }


def channel_mix(p, x, cfg, state: Optional[Dict] = None, snap_at=None):
    b, t, d = x.shape
    st = state or {"cm_x_prev": jnp.zeros((b, d), jnp.float32)}
    xk = _token_shift(x, st["cm_x_prev"], p["mu_k"])
    xr = _token_shift(x, st["cm_x_prev"], p["mu_r"])
    k = jnp.square(jax.nn.relu(dense(p["wk"], xk)))
    r = jax.nn.sigmoid(dense(p["wr"], xr))
    y = r * dense(p["wv"], k)
    if snap_at is None:
        x_prev = x[:, -1]
    else:
        x_prev = jnp.take_along_axis(
            x, jnp.clip(snap_at - 1, 0, t - 1)[:, None, None], axis=1)[:, 0]
    return y, {"cm_x_prev": x_prev.astype(jnp.float32)}
