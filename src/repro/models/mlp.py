"""Dense (gated) MLP block: SwiGLU / GeGLU / plain."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models import param as pm
from repro.models.layers import dense, act_fn
from repro.distributed.sharding import constrain


def mlp_init(key, d_model, d_ff, gated=True):
    ks = pm.split(key, 3)
    p = {"w_in": pm.dense_init(ks[0], d_model, d_ff),
         "w_out": pm.dense_init(ks[1], d_ff, d_model, scale=d_ff ** -0.5)}
    if gated:
        p["w_gate"] = pm.dense_init(ks[2], d_model, d_ff)
    return p


def mlp(p, x, act="silu", gated=True):
    h = dense(p["w_in"], x)
    if gated:
        g = dense(p["w_gate"], x)
        h = act_fn(act)(g) * h
    else:
        h = act_fn(act)(h)
    h = constrain(h, ("batch", None, "ffn"))
    return dense(p["w_out"], h)
