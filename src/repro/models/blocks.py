"""Transformer blocks + scan-over-layers stack.

The stack is organized as ``n_periods`` repetitions of the config's layer
pattern (plus an unrolled tail when depth % period != 0), scanned with
``lax.scan`` so HLO size is O(1) in depth. Params and per-layer state are
stacked along a leading period axis.

Block kinds: "global" / "local" attention, "recurrent" (RG-LRU),
"rwkv" (RWKV6). A block optionally carries a cross-attention sub-layer
(VLM / enc-dec decoder).

Modes (driven by arguments, not flags):
  * train:       cache=None                     -> causal self-attention
  * prefill:     cache given, write_kv=True     -> attend self, write cache
  * decode/verify: cache given, write_kv=False  -> attend [cache ++ self]
                   with optional ``extra_mask`` (tree mask); new KV returned
                   to the caller for post-acceptance commit.

KV storage is pluggable per block state (models/kvcache.py): a dense
[B, cap, H, D] buffer, or — for global layers under ``cache_impl="paged"``
— a page pool + per-row page table (``"pt"`` key). Reads go through the
logical page view, writes through the tail-page scatter; both are
value-identical to the dense layout at every committed position.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models import kvcache as kvc
from repro.models import param as pm
from repro.models.attention import (attn_init, project_qkv, out_proj, attend,
                                    attend_cache_plus_block)
from repro.models.layers import rmsnorm, rmsnorm_init, dense
from repro.models.mlp import mlp, mlp_init
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import rwkv as rwkv_lib
from repro.distributed.sharding import constrain


# ------------------------------------------------------------ period spec --
@dataclasses.dataclass(frozen=True)
class BlockSpec2:
    kind: str            # global | local | recurrent | rwkv
    cross: bool = False


def period_spec(cfg: ModelConfig) -> Tuple[Tuple[BlockSpec2, ...], int,
                                           Tuple[BlockSpec2, ...]]:
    """Return (period, n_periods, tail) covering cfg.num_layers layers."""
    pat = list(cfg.layer_pattern)
    ce = cfg.cross_attn_every
    if ce:
        # expand pattern to lcm so cross alignment is periodic
        import math
        plen = len(pat)
        eff = math.lcm(plen, ce)
        pat = (pat * (eff // plen))
        spec = tuple(BlockSpec2(k, cross=((i + 1) % ce == 0))
                     for i, k in enumerate(pat))
    else:
        spec = tuple(BlockSpec2(k) for k in pat)
    plen = len(spec)
    n_periods = cfg.num_layers // plen
    tail_n = cfg.num_layers - n_periods * plen
    # tail layers continue the pattern
    tail = tuple(
        BlockSpec2(pat[i % len(pat)] if not ce else spec[i % plen].kind,
                   cross=spec[i % plen].cross if ce else False)
        for i in range(tail_n))
    return spec, n_periods, tail


# ----------------------------------------------------------------- block ---
def block_init(key, cfg: ModelConfig, spec: BlockSpec2):
    ks = pm.split(key, 8)
    p: Dict[str, Any] = {"ln1": rmsnorm_init(cfg.d_model)}
    if spec.kind in ("global", "local"):
        p["attn"] = attn_init(ks[0], cfg)
    elif spec.kind == "recurrent":
        p["rec"] = rglru_lib.rglru_block_init(ks[0], cfg)
    elif spec.kind == "rwkv":
        p["rwkv_tm"] = rwkv_lib.time_mix_init(ks[0], cfg)
    else:
        raise ValueError(spec.kind)
    if spec.kind == "rwkv":
        p["ln2"] = rmsnorm_init(cfg.d_model)
        p["rwkv_cm"] = rwkv_lib.channel_mix_init(ks[1], cfg)
    else:
        p["ln2"] = rmsnorm_init(cfg.d_model)
        if cfg.moe is not None:
            p["ffn"] = moe_lib.moe_init(ks[1], cfg)
        else:
            p["ffn"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_gated)
    if spec.cross:
        p["ln_x"] = rmsnorm_init(cfg.d_model)
        p["xattn"] = attn_init(ks[2], cfg, cross=True)
    if cfg.use_post_norm:
        p["ln1_post"] = rmsnorm_init(cfg.d_model)
        p["ln2_post"] = rmsnorm_init(cfg.d_model)
    return p


def block_state_init(cfg: ModelConfig, spec: BlockSpec2, batch: int,
                     max_len: int, ctx_len: int = 0, dtype=jnp.bfloat16,
                     cache_impl: str = "dense", page_size: int = 64,
                     pool_pages: int = 0, page_table=None,
                     alloc_pool: bool = True):
    """Per-layer decoding state.

    cache_impl="paged": *global* attention layers store their KV as a
    shared page pool [pool_pages, page, Hkv, Dh] plus a per-row page table
    ``pt`` [B, max_pages] (see models/kvcache.py). Local sliding-window
    layers keep dense rolling buffers (window-capped capacity; rolling
    position recovery does not compose with page indirection), and
    recurrent / rwkv states are untouched.

    alloc_pool=False: leave the paged k/v pools as None placeholders —
    the caller substitutes retained device buffers (borrowed-pool wave
    turnover) and the zeroed pool allocation is skipped entirely.
    """
    st: Dict[str, Any] = {}
    hkv, dh = cfg.num_kv_heads, cfg.head_dim
    if spec.kind == "global" and cache_impl == "paged":
        if alloc_pool:
            st["k"] = kvc.init_pool(pool_pages, page_size, hkv, dh, dtype)
            st["v"] = kvc.init_pool(pool_pages, page_size, hkv, dh, dtype)
        else:
            st["k"] = None
            st["v"] = None
        # copy=True: the wave-level table is shared by every paged cache;
        # each leaf needs its own buffer or donating the state fails with
        # "attempt to donate the same buffer twice"
        st["pt"] = jnp.array(page_table, jnp.int32, copy=True)
    elif spec.kind in ("global", "local"):
        cap = max_len if spec.kind == "global" else min(max_len, _window_cap(cfg))
        st["k"] = jnp.zeros((batch, cap, hkv, dh), dtype)
        st["v"] = jnp.zeros((batch, cap, hkv, dh), dtype)
    elif spec.kind == "recurrent":
        st.update(rglru_lib.rglru_state_init(cfg, batch))
    elif spec.kind == "rwkv":
        st.update(rwkv_lib.rwkv_state_init(cfg, batch))
    if spec.cross:
        st["ck"] = jnp.zeros((batch, max(ctx_len, 1), hkv, dh), dtype)
        st["cv"] = jnp.zeros((batch, max(ctx_len, 1), hkv, dh), dtype)
    return st


def _window_cap(cfg: ModelConfig) -> int:
    # local layers never need more KV than the window
    return cfg.sliding_window


def block_apply(p, x, cfg: ModelConfig, spec: BlockSpec2, *,
                state=None, cache_len=None, positions=None,
                write_kv: bool = False, extra_mask=None, ctx=None,
                attn_impl: str = "auto", kv_chunk: int = 1024,
                snap_at=None, attend_cache_on_write: bool = False):
    """Apply one block. Returns (y, new_state, kv_out).

    kv_out: (k_self, v_self) of this pass (None for attention-free blocks) —
    used by verification to commit accepted KV without recompute.
    snap_at: optional [B] — for replay-commit: recurrent states reflect
    exactly snap_at consumed tokens; attention KV writes beyond snap_at are
    dropped.
    """
    new_state = dict(state) if state is not None else None
    kv_out = None
    window = cfg.sliding_window if spec.kind == "local" else None

    # ---- cross-attention sub-layer (before self, Flamingo/Llama-vision) ----
    if spec.cross:
        h = rmsnorm(p["ln_x"], x, cfg.norm_eps)
        if ctx is not None:
            # (re)compute cross KV from context; cache it if we have state
            ck = dense(p["xattn"]["wk"], ctx).reshape(
                ctx.shape[0], ctx.shape[1], cfg.num_kv_heads, cfg.head_dim)
            cv = dense(p["xattn"]["wv"], ctx).reshape(
                ctx.shape[0], ctx.shape[1], cfg.num_kv_heads, cfg.head_dim)
            if new_state is not None:
                new_state["ck"] = ck.astype(new_state["ck"].dtype)
                new_state["cv"] = cv.astype(new_state["cv"].dtype)
        else:
            assert state is not None, "cross block needs ctx or cached cross-KV"
            ck, cv = state["ck"], state["cv"]
        b, t, _ = h.shape
        q = dense(p["xattn"]["wq"], h).reshape(b, t, cfg.num_heads, cfg.head_dim)
        xo = attend(q, ck, cv, causal=False, q_offset=0,
                    attn_softcap=cfg.attn_softcap, impl=attn_impl,
                    kv_chunk=kv_chunk)
        x = x + out_proj(p["xattn"], xo)

    # ---- mixer ----
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if spec.kind in ("global", "local"):
        q, k, v = project_qkv(p["attn"], h, cfg, positions=positions)
        if state is None:
            y = attend(q, k, v, causal=True, q_offset=0, window=window,
                       extra_mask=extra_mask, attn_softcap=cfg.attn_softcap,
                       impl=attn_impl, kv_chunk=kv_chunk)
        else:
            paged = kvc.is_paged(state)
            rolling = spec.kind == "local"

            def cache_view():
                """Logical [B, cap, H, D] K/V view of this block's cache
                (the pool gathered in page-table order when paged)."""
                if paged:
                    ck = kvc.pool_view(state["k"], state["pt"])
                    cv = kvc.pool_view(state["v"], state["pt"])
                else:
                    ck, cv = state["k"], state["v"]
                return ck.astype(k.dtype), cv.astype(v.dtype)

            def write_cache(buf_key, new):
                """Append ``new`` at cache_len: tail-page scatter (paged)
                or contiguous slice write / rolling scatter (dense)."""
                if not paged:
                    return _scatter_kv(state[buf_key], new, cache_len,
                                       rolling, write_len=snap_at)
                t = new.shape[1]
                clen = jnp.broadcast_to(jnp.asarray(cache_len).reshape(-1),
                                        (new.shape[0],))
                pos = clen[:, None] + jnp.arange(t, dtype=jnp.int32)[None, :]
                valid = (jnp.arange(t)[None, :] < snap_at[:, None]
                         if snap_at is not None else None)
                return kvc.pool_scatter(state[buf_key], state["pt"], new,
                                        pos, valid=valid)

            cap = (kvc.logical_len(state) if paged else state["k"].shape[1])
            if write_kv:
                if attend_cache_on_write:
                    # replay-commit: attend [cache ++ block], then write
                    ck, cv = cache_view()
                    kk = jnp.concatenate([ck, k], 1)
                    vv = jnp.concatenate([cv, v], 1)
                    q_abs = (positions if positions is not None else
                             jnp.asarray(cache_len)[..., None]
                             + jnp.arange(q.shape[1]))
                    y = attend_cache_plus_block(
                        q, kk, vv, cache_cap=cap, cache_len=cache_len,
                        q_abs=q_abs, window=window, extra_mask=extra_mask,
                        attn_softcap=cfg.attn_softcap, impl=attn_impl,
                        kv_chunk=kv_chunk, rolling=rolling)
                else:
                    # prefill from empty context: causal self-attention
                    y = attend(q, k, v, causal=True, q_offset=0, window=window,
                               attn_softcap=cfg.attn_softcap, impl=attn_impl,
                               kv_chunk=kv_chunk)
                new_state["k"] = write_cache("k", k)
                new_state["v"] = write_cache("v", v)
            else:
                # decode/verify: single softmax over [cache ++ self-block]
                if positions is not None:
                    q_abs = positions
                else:
                    q_abs = jnp.asarray(cache_len)[..., None] + jnp.arange(
                        q.shape[1])
                y = None
                from repro.distributed import spdecode
                axis = spdecode.kv_seq_axis()
                if axis is not None and not paged:
                    from repro.distributed.sharding import active_mesh
                    n_shards = dict(zip(active_mesh().axis_names,
                                        active_mesh().devices.shape))[axis]
                    if cap % n_shards == 0 and cap // n_shards >= 128:
                        blk_mask = extra_mask
                        if blk_mask is None:
                            tb = k.shape[1]
                            blk_mask = jnp.tril(jnp.ones((tb, tb), bool))
                        y = spdecode.sharded_cache_attend(
                            q, state["k"].astype(k.dtype),
                            state["v"].astype(v.dtype), k, v,
                            cache_len=cache_len, q_abs=q_abs, window=window,
                            attn_softcap=cfg.attn_softcap, blk_mask=blk_mask,
                            rolling=rolling, kv_chunk=kv_chunk)
                elif axis is not None and paged and not rolling \
                        and window is None:
                    # paged cascade verify under shard_map: page payloads
                    # sharded on the within-page axis, page ids global —
                    # each shard gathers its slice of every table page and
                    # partials merge via the LSE psum (fp32: token
                    # identity with the single-device engine)
                    page_size = state["k"].shape[-3]
                    if page_size % spdecode.kv_seq_shards() == 0:
                        blk_mask = extra_mask
                        if blk_mask is None:
                            tb = k.shape[1]
                            blk_mask = jnp.tril(jnp.ones((tb, tb), bool))
                        y = spdecode.sharded_paged_cache_attend(
                            q, state["k"].astype(k.dtype),
                            state["v"].astype(v.dtype), state["pt"], k, v,
                            cache_len=cache_len, q_abs=q_abs,
                            attn_softcap=cfg.attn_softcap, blk_mask=blk_mask,
                            page_size=page_size, kv_chunk=kv_chunk,
                            read_impl=cfg.attn_impl)
                if y is None and cfg.attn_impl == "pallas" and axis is None:
                    # kernelized read path (cfg.attn_impl, a jit-static):
                    # cascade kernels consume the cache buffers directly —
                    # paged global layers: pool + page table, no per-cycle
                    # pool_view gather; sliding-window local layers: the
                    # dense kernel over the rolling buffer (cap = true
                    # buffer capacity; split padding is masked dead inside
                    # the kernel, so non-block-aligned window capacities
                    # recover exact rolling positions).
                    from repro.kernels import ops as kops
                    blk_mask = extra_mask
                    if blk_mask is None:
                        tb = k.shape[1]
                        blk_mask = jnp.tril(jnp.ones((tb, tb), bool))
                        if window is not None:
                            # mirror attend_cache_plus_block's default
                            # in-block window masking (tokens more than
                            # `window` apart inside one block)
                            ji = jnp.arange(tb)[None, :]
                            ii = jnp.arange(tb)[:, None]
                            blk_mask &= ji > (ii - window)
                    qa2 = jnp.broadcast_to(
                        jnp.asarray(q_abs, jnp.int32).reshape(-1, q.shape[1]),
                        (q.shape[0], q.shape[1]))
                    if paged:
                        y = kops.cascade_attention_paged(
                            q, state["k"].astype(k.dtype),
                            state["v"].astype(v.dtype), state["pt"], k, v,
                            cache_len=cache_len, q_abs=qa2,
                            tree_mask=blk_mask, window=window,
                            attn_softcap=cfg.attn_softcap, layout="BTHD")
                    else:
                        y = kops.cascade_attention(
                            q, state["k"].astype(k.dtype),
                            state["v"].astype(v.dtype), k, v,
                            cache_len=cache_len, q_abs=qa2,
                            tree_mask=blk_mask, window=window,
                            attn_softcap=cfg.attn_softcap, rolling=rolling,
                            layout="BTHD")
                if y is None:
                    ck, cv = cache_view()
                    kk = jnp.concatenate([ck, k], axis=1)
                    vv = jnp.concatenate([cv, v], axis=1)
                    y = attend_cache_plus_block(
                        q, kk, vv, cache_cap=cap, cache_len=cache_len,
                        q_abs=q_abs, window=window, extra_mask=extra_mask,
                        attn_softcap=cfg.attn_softcap, impl=attn_impl,
                        kv_chunk=kv_chunk, rolling=rolling)
                kv_out = (k, v)
        y = out_proj(p["attn"], y)
    elif spec.kind == "recurrent":
        y, rec_state = rglru_lib.rglru_block(
            p["rec"], h, cfg,
            state={k2: state[k2] for k2 in rglru_lib.STATE_KEYS} if state is not None else None,
            snap_at=snap_at)
        if new_state is not None:
            new_state.update(rec_state)
    elif spec.kind == "rwkv":
        y, tm_state = rwkv_lib.time_mix(
            p["rwkv_tm"], h, cfg,
            state={k2: state[k2] for k2 in rwkv_lib.TM_STATE_KEYS} if state is not None else None,
            snap_at=snap_at)
        if new_state is not None:
            new_state.update(tm_state)
    else:
        raise ValueError(spec.kind)

    if cfg.use_post_norm:
        y = rmsnorm(p["ln1_post"], y, cfg.norm_eps)
    x = x + y
    x = constrain(x, ("batch", "act_seq", "embed"))

    # ---- ffn ----
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if spec.kind == "rwkv":
        y, cm_state = rwkv_lib.channel_mix(
            p["rwkv_cm"], h, cfg,
            state={k2: state[k2] for k2 in rwkv_lib.CM_STATE_KEYS} if state is not None else None,
            snap_at=snap_at)
        if new_state is not None:
            new_state.update(cm_state)
    elif cfg.moe is not None:
        y = moe_lib.moe_apply(p["ffn"], h, cfg)
    else:
        y = mlp(p["ffn"], h, cfg.mlp_act, cfg.mlp_gated)
    if cfg.use_post_norm:
        y = rmsnorm(p["ln2_post"], y, cfg.norm_eps)
    x = x + y
    x = constrain(x, ("batch", "act_seq", "embed"))
    return x, new_state, kv_out


def _scatter_kv(buf, new, start, rolling: bool, write_len=None):
    """Write [B,T,H,D] into [B,cap,H,D] at ``start`` (scalar or per-example
    [B]; mod cap when rolling). ``write_len`` [B]: entries beyond it are
    dropped (partial-acceptance replay)."""
    b, cap = buf.shape[:2]
    t = new.shape[1]
    new = new.astype(buf.dtype)
    start = jnp.asarray(start)
    if rolling and t >= cap and write_len is None:
        # only the last ``cap`` tokens survive a full wrap; write them in one
        # aligned pass (avoids duplicate-index scatter nondeterminism)
        new = new[:, -cap:]
        start = start + (t - cap)
        t = cap
    if start.ndim == 0 and write_len is None:
        if not rolling:
            return jax.lax.dynamic_update_slice(buf, new, (0, start, 0, 0))
        idx = jnp.mod(start + jnp.arange(t), cap)
        return buf.at[:, idx].set(new)
    if start.ndim == 0:
        start = jnp.broadcast_to(start, (b,))
    idx = start[:, None] + jnp.arange(t)[None, :]
    if rolling:
        idx = jnp.mod(idx, cap)
    if write_len is not None:
        keep = jnp.arange(t)[None, :] < write_len[:, None]
        if rolling:
            # only the last ``cap`` valid tokens survive a wrap; dropping
            # the earlier ones keeps the scatter free of duplicate indices
            keep &= jnp.arange(t)[None, :] >= (write_len[:, None] - cap)
        idx = jnp.where(keep, idx, cap + 1)
    bidx = jnp.broadcast_to(jnp.arange(b)[:, None], (b, t))
    return buf.at[bidx, idx].set(new, mode="drop")


# Back-compat alias: the cache++block read path moved to
# repro.models.attention (one home for every attention impl).
_attend_cache_plus_block = attend_cache_plus_block
