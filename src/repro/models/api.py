"""Unified per-architecture model API: init / train loss / batch synthesis.

Dispatches on the config family: encoder-decoder (whisper) composes an
encoder; VLM/audio batches carry stub modality embeddings.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig, ShapeSpec
from repro.models import encdec, lm


def init_model(key, cfg: ModelConfig):
    if cfg.is_encoder_decoder:
        return encdec.encdec_init(key, cfg)
    return lm.lm_init(key, cfg)


def decoder_params(params, cfg: ModelConfig):
    return params["decoder"] if cfg.is_encoder_decoder else params


def train_loss(params, batch, cfg: ModelConfig, **kw):
    if cfg.is_encoder_decoder:
        return encdec.encdec_loss(params, batch, cfg, **kw)
    ctx = batch.get("image_embeds")
    return lm.loss_fn(params, batch, cfg, ctx=ctx, **kw)


def make_batch(key, cfg: ModelConfig, batch: int, seq: int) -> Dict[str, Any]:
    """Random but well-formed training batch (smoke tests / dry-run shapes)."""
    ks = jax.random.split(key, 3)
    toks = jax.random.randint(ks[0], (batch, seq), 0, cfg.vocab_size,
                              dtype=jnp.int32)
    out = {"tokens": toks,
           "labels": jnp.roll(toks, -1, axis=1),
           "mask": jnp.ones((batch, seq), jnp.float32)}
    if cfg.is_encoder_decoder:
        out["audio_feats"] = jax.random.normal(
            ks[1], (batch, cfg.enc_max_len, cfg.d_model), jnp.bfloat16)
    elif cfg.cross_attn_every:
        out["image_embeds"] = jax.random.normal(
            ks[2], (batch, max(cfg.num_vision_tokens, 1), cfg.d_model),
            jnp.bfloat16)
    return out


def batch_specs(cfg: ModelConfig, batch: int, seq: int):
    """ShapeDtypeStruct stand-ins for ``make_batch`` (no allocation)."""
    sds = jax.ShapeDtypeStruct
    out = {"tokens": sds((batch, seq), jnp.int32),
           "labels": sds((batch, seq), jnp.int32),
           "mask": sds((batch, seq), jnp.float32)}
    if cfg.is_encoder_decoder:
        out["audio_feats"] = sds((batch, cfg.enc_max_len, cfg.d_model),
                                 jnp.bfloat16)
    elif cfg.cross_attn_every:
        out["image_embeds"] = sds(
            (batch, max(cfg.num_vision_tokens, 1), cfg.d_model), jnp.bfloat16)
    return out
