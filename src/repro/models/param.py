"""Parameter initializers for the functional module system.

Params are plain nested dicts of jnp arrays. Sharding is attached by
name-based logical-axis rules (see ``repro.distributed.sharding``), so init
stays trivially simple and scan-stackable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def trunc_normal(key, shape, dtype=jnp.float32, stddev=0.02):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * stddev).astype(dtype)


def dense_init(key, d_in, d_out, dtype=jnp.float32, scale=None):
    stddev = scale if scale is not None else (1.0 / (d_in ** 0.5))
    return trunc_normal(key, (d_in, d_out), dtype, stddev)


def zeros(shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)


def ones(shape, dtype=jnp.float32):
    return jnp.ones(shape, dtype)


def split(key, n):
    return list(jax.random.split(key, n))
