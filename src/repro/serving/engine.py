"""Batched D2SD serving engine: continuous slot-refill batching.

Requests queue up and are served FIFO through a fixed-size batch of row
*slots* over one typed :class:`~repro.core.state.EngineState`:

* **Per-slot prefill** — each request is prefilled independently into its
  row via :func:`~repro.core.state.prefill_row` (a batch-1 prefill spliced
  in with :meth:`EngineState.adopt_row`), so one running batch mixes
  arbitrary prompt lengths AND arbitrary ``max_new`` budgets; there are no
  uniform-prompt-length waves.
* **Early-exit masking** — before every decode cycle the engine pushes a
  per-row ``active`` mask into the state; rows whose request already hit
  its budget (or whose slot is idle) draft a degenerate root-only tree and
  commit nothing, so they stop mutating KV / feature caches and stop
  polluting acceptance statistics (disable with ``early_exit=False``).
* **Slot refill** — the moment a request finishes, it retires into
  ``done`` and the FIFO head of the queue is prefilled into the vacated
  row, keeping the batch full under sustained traffic (disable with
  ``refill=False`` to get drain-the-wave batching for A/B comparison; see
  ``benchmarks/serving_bench.py``).

The per-cycle :meth:`ServingEngine.step` API owns ONE decode cycle, so the
host loop can interleave submissions, refills, and stats collection.
Aggregate stats track tokens actually committed per request
(``min(filled, max_new)``), acceptance ``alpha`` over *active* row-cycles
only, and ``wasted_row_cycles`` — cycles a batch row spent without a live,
unfinished request (the quantity early-exit + refill minimizes).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pipeline as pl
from repro.core.state import EngineState, prefill_row


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # [P]
    max_new: int
    out: Optional[np.ndarray] = None
    n_cycles: int = 0
    latency_s: float = 0.0
    t_start: float = 0.0


@dataclasses.dataclass
class Wave:
    """One running batch: typed engine state + per-slot request books."""
    requests: List[Optional[Request]]   # slot -> live request (None = idle)
    state: EngineState
    bufs: np.ndarray            # [B, cap] committed tokens (slot 0 = anchor)
    filled: np.ndarray          # [B] tokens committed so far
    targets: np.ndarray         # [B] per-request max_new (0 for idle slots)
    t0: float
    cycles: int = 0

    @property
    def done(self) -> bool:
        return all(r is None for r in self.requests)


class ServingEngine:
    def __init__(self, bundle: pl.SpecBundle, batch_size: int = 8,
                 seed: int = 0, early_exit: bool = True,
                 refill: bool = True):
        self.bundle = bundle
        self.batch_size = batch_size
        self.early_exit = early_exit
        self.refill = refill
        self.queue: List[Request] = []
        self.done: List[Request] = []
        self.key = jax.random.PRNGKey(seed)
        self._next_uid = 0
        self.wave: Optional[Wave] = None
        # shares pipeline's module-level trace cache across engine instances
        self._cycle = lambda s, k: pl._cycle_jit(self.bundle, s, k,
                                                 collect_stats=False)
        self.stats = {"tokens": 0, "cycles": 0, "accepted": 0,
                      "wall_s": 0.0, "waves": 0, "alpha": 0.0,
                      "wasted_row_cycles": 0, "refills": 0}
        self._alpha_num = 0
        self._alpha_den = 0

    def submit(self, prompt: np.ndarray, max_new: int) -> int:
        # Monotonic uid: len(queue)+len(done) would collide once a wave
        # drains the queue mid-run.
        uid = self._next_uid
        self._next_uid += 1
        self.queue.append(Request(uid, np.asarray(prompt, np.int32),
                                  max_new))
        return uid

    def _next_wave(self) -> List[Request]:
        # FIFO: the wave anchors on the oldest queued request. (Re-sorting
        # by prompt length let sustained short-prompt traffic starve an
        # early long-prompt request forever; per-slot prefill removed the
        # uniform-length constraint that motivated the sort.)
        take = self.queue[: self.batch_size]
        self.queue = self.queue[len(take):]
        return take

    # ------------------------------------------------------ step API ------
    def start_wave(self) -> bool:
        """Allocate + prefill the next running batch. False if queue empty."""
        assert self.wave is None, "finish the active wave first"
        reqs = self._next_wave()
        if not reqs:
            return False
        b = len(reqs)
        g = self.bundle.spec.gamma
        # size caches for the wave plus the next batch of likely refill
        # candidates — not the whole queue, or one huge queued request
        # would inflate every slot's KV/feature allocation; requests that
        # don't fit simply wait for the next wave (see _fits)
        cand = reqs + self.queue[: self.batch_size]
        cap = max(self._bufs_needed(r, g) for r in cand)
        max_len = max(self._cache_needed(r, g) for r in cand)
        state = pl.engine_init(self.bundle, b, max_len)
        state = state.replace(active=jnp.zeros((b,), bool))
        self.wave = Wave(requests=[None] * b, state=state,
                         bufs=np.zeros((b, cap), np.int32),
                         filled=np.zeros((b,), np.int64),
                         targets=np.zeros((b,), np.int64),
                         t0=time.time())
        for i, r in enumerate(reqs):
            self._install(i, r)
            if self.wave.filled[i] >= self.wave.targets[i]:
                # satisfied by the prefill alone (max_new <= 1): retire
                # (and possibly refill) without paying a decode cycle
                self._retire(i)
        if self.wave.done:
            self._finish_wave()
        return True

    def _install(self, slot: int, r: Request) -> None:
        """Prefill ``r`` into ``slot`` of the running batch (slot refill)."""
        w = self.wave
        self.key, sub = jax.random.split(self.key)
        w.state = prefill_row(self.bundle, w.state, slot, r.prompt, key=sub,
                              temperature=self.bundle.spec.temperature)
        w.bufs[slot] = 0
        w.bufs[slot, 0] = int(np.asarray(w.state.anchor)[slot])
        w.filled[slot] = 1
        w.targets[slot] = r.max_new
        w.requests[slot] = r
        r.t_start = time.time()
        r.n_cycles = 0

    # ---- sizing: single source of truth for allocation and admission ----
    @staticmethod
    def _bufs_needed(r: Request, g: int) -> int:
        """Output-buffer slots: budget + worst-case overshoot + anchor."""
        return r.max_new + g + 1

    @staticmethod
    def _cache_needed(r: Request, g: int) -> int:
        """KV/feature-cache positions: prompt + budget + draft headroom
        (the same sizing rule as ``generate``'s default max_len)."""
        return len(r.prompt) + r.max_new + 2 * g + 8

    def _fits(self, r: Request) -> bool:
        """Can ``r`` be adopted into the current wave's allocation?"""
        w = self.wave
        g = self.bundle.spec.gamma
        return (self._bufs_needed(r, g) <= w.bufs.shape[1]
                and self._cache_needed(r, g) <= w.state.max_len)

    def _host_active(self) -> np.ndarray:
        """[B] rows holding a request that still wants tokens."""
        w = self.wave
        return np.array([r is not None and w.filled[i] < w.targets[i]
                         for i, r in enumerate(w.requests)])

    def step(self) -> bool:
        """Run ONE decode cycle for the running batch and bank its tokens.

        Finished requests retire immediately and (with ``refill``) their
        slot adopts the FIFO head of the queue via a per-slot prefill.
        Returns True while any slot still has an unfinished request;
        False once the wave has closed — including the case where
        ``start_wave`` already finished it outright (a burst of
        ``max_new <= 1`` requests satisfied by their prefills).
        """
        w = self.wave
        if w is None:
            return False
        b = len(w.requests)
        active = self._host_active()
        # push the mask: with early_exit, finished/idle rows cost nothing
        # and commit nothing; without it they keep running full cycles
        # (legacy behavior, kept for A/B benchmarking)
        w.state = w.state.replace(
            active=jnp.asarray(active) if self.early_exit
            else jnp.ones((b,), bool))
        self.key, sub = jax.random.split(self.key)
        w.state, out = self._cycle(w.state, sub)
        toks = np.asarray(out["tokens"])
        n_out = np.asarray(out["n_out"])
        cap = w.bufs.shape[1]
        w.cycles += 1
        # stats: only rows that were actively serving a request count
        # toward acceptance; the rest are wasted batch capacity
        self.stats["wasted_row_cycles"] += int(b - active.sum())
        self._alpha_num += int(n_out[active].sum())
        self._alpha_den += int(active.sum())
        self.stats["accepted"] += int(np.maximum(n_out[active] - 1, 0).sum())
        for i in range(b):
            r = w.requests[i]
            if r is None:
                continue
            if active[i]:
                m = min(int(n_out[i]), cap - int(w.filled[i]))
                if m > 0:
                    w.bufs[i, w.filled[i]: w.filled[i] + m] = toks[i, :m]
                w.filled[i] = min(w.filled[i] + int(n_out[i]), cap)
                r.n_cycles += 1
            if w.filled[i] >= w.targets[i] or r.n_cycles > r.max_new + 8:
                self._retire(i)
        if w.done:
            self._finish_wave()
            return False
        return True

    def _retire(self, slot: int) -> None:
        w = self.wave
        while True:
            r = w.requests[slot]
            r.out = w.bufs[slot, : r.max_new].copy()
            r.latency_s = time.time() - r.t_start
            self.done.append(r)
            # count tokens actually committed: a cycle-cap bailout can
            # retire a request with filled < max_new, which must not
            # inflate tokens_per_s
            self.stats["tokens"] += int(min(w.filled[slot], r.max_new))
            w.requests[slot] = None
            w.targets[slot] = 0
            if not (self.refill and self.queue
                    and self._fits(self.queue[0])):
                return
            self._install(slot, self.queue.pop(0))
            self.stats["refills"] += 1
            if w.filled[slot] < w.targets[slot]:
                return
            # adopted request was satisfied by its prefill alone
            # (max_new <= 1): keep draining the queue into this slot

    def _finish_wave(self) -> None:
        w = self.wave
        dt = time.time() - w.t0
        self.stats["cycles"] += w.cycles * len(w.requests)
        self.stats["wall_s"] += dt
        self.stats["waves"] += 1
        self.stats["alpha"] = (self._alpha_num / self._alpha_den
                               if self._alpha_den else 0.0)
        self.wave = None

    # ----------------------------------------------------- drain loop -----
    def run(self) -> Dict:
        while self.queue or self.wave is not None:
            if self.wave is None and not self.start_wave():
                break
            # start_wave can finish a wave outright (all-max_new<=1 burst)
            while self.wave is not None and self.step():
                pass
        s = dict(self.stats)
        s["tokens_per_s"] = (s["tokens"] / s["wall_s"]
                             if s["wall_s"] else 0.0)
        return s
