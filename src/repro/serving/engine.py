"""Batched D2SD serving engine.

Wave-based continuous batching over the typed decode-engine API: requests
queue up, waves of ``batch_size`` uniform-prompt-length requests prefill
once into one :class:`~repro.core.state.EngineState` and then advance via
the per-cycle :meth:`ServingEngine.step` API. Because ``step`` owns one
decode cycle (not a whole ``generate`` call), a wave can mix requests with
different ``max_new`` without re-prefilling: finished requests simply stop
accumulating tokens and the wave retires when the last one is done.
Tracks per-request and aggregate acceptance/latency statistics.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.core import pipeline as pl
from repro.core.state import EngineState


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # [P]
    max_new: int
    out: Optional[np.ndarray] = None
    n_cycles: int = 0
    latency_s: float = 0.0


@dataclasses.dataclass
class Wave:
    """One in-flight batch: typed engine state + per-request output books."""
    requests: List[Request]
    state: EngineState
    bufs: np.ndarray            # [B, cap] committed tokens (slot 0 = anchor)
    filled: np.ndarray          # [B] tokens committed so far
    targets: np.ndarray         # [B] per-request max_new
    t0: float
    cycles: int = 0

    @property
    def done(self) -> bool:
        return bool((self.filled >= self.targets).all())


class ServingEngine:
    def __init__(self, bundle: pl.SpecBundle, batch_size: int = 8,
                 seed: int = 0):
        self.bundle = bundle
        self.batch_size = batch_size
        self.queue: List[Request] = []
        self.done: List[Request] = []
        self.key = jax.random.PRNGKey(seed)
        self._next_uid = 0
        self.wave: Optional[Wave] = None
        # shares pipeline's module-level trace cache across engine instances
        self._cycle = lambda s, k: pl._cycle_jit(self.bundle, s, k,
                                                 collect_stats=False)
        self.stats = {"tokens": 0, "cycles": 0, "accepted": 0,
                      "wall_s": 0.0, "waves": 0, "alpha": 0.0}
        self._alpha_num = 0
        self._alpha_den = 0

    def submit(self, prompt: np.ndarray, max_new: int) -> int:
        # Monotonic uid: len(queue)+len(done) would collide once a wave
        # drains the queue mid-run.
        uid = self._next_uid
        self._next_uid += 1
        self.queue.append(Request(uid, np.asarray(prompt, np.int32),
                                  max_new))
        return uid

    def _next_wave(self) -> List[Request]:
        if not self.queue:
            return []
        # group by prompt length (uniform-length waves)
        self.queue.sort(key=lambda r: len(r.prompt))
        plen = len(self.queue[0].prompt)
        wave = [r for r in self.queue if len(r.prompt) == plen]
        wave = wave[: self.batch_size]
        for r in wave:
            self.queue.remove(r)
        return wave

    # ------------------------------------------------------ step API ------
    def start_wave(self) -> bool:
        """Prefill the next wave of requests. Returns False if queue empty."""
        assert self.wave is None, "finish the active wave first"
        reqs = self._next_wave()
        if not reqs:
            return False
        prompts = np.stack([r.prompt for r in reqs])
        b, p = prompts.shape
        g = self.bundle.spec.gamma
        targets = np.array([r.max_new for r in reqs], np.int64)
        cap = int(targets.max()) + g + 1
        max_len = p + cap + 2 * g + 8
        state = pl.engine_init(self.bundle, b, max_len)
        self.key, sub = jax.random.split(self.key)
        state = pl.prefill(self.bundle, state, prompts, key=sub,
                           temperature=self.bundle.spec.temperature)
        bufs = np.zeros((b, cap), np.int32)
        bufs[:, 0] = np.asarray(state.anchor)
        self.wave = Wave(requests=reqs, state=state, bufs=bufs,
                         filled=np.ones((b,), np.int64), targets=targets,
                         t0=time.time())
        return True

    def step(self) -> bool:
        """Run ONE decode cycle for the active wave and bank its tokens.

        Returns True while the wave still has unfinished requests; on the
        cycle that finishes the last request the wave retires into ``done``
        and False is returned.
        """
        w = self.wave
        assert w is not None, "no active wave — call start_wave()"
        self.key, sub = jax.random.split(self.key)
        w.state, out = self._cycle(w.state, sub)
        toks = np.asarray(out["tokens"])
        n_out = np.asarray(out["n_out"])
        cap = w.bufs.shape[1]
        for i in range(len(w.requests)):
            m = min(int(n_out[i]), cap - int(w.filled[i]))
            if m > 0:
                w.bufs[i, w.filled[i]: w.filled[i] + m] = toks[i, :m]
        w.filled = np.minimum(w.filled + n_out, cap)
        w.cycles += 1
        self._alpha_num += int(n_out.sum())
        self._alpha_den += len(w.requests)
        if w.done or w.cycles > int(w.targets.max()) + 8:
            self._finish_wave()
            return False
        return True

    def _finish_wave(self) -> None:
        w = self.wave
        dt = time.time() - w.t0
        for i, r in enumerate(w.requests):
            r.out = w.bufs[i, : r.max_new]
            r.n_cycles = w.cycles
            r.latency_s = dt
            self.done.append(r)
        self.stats["tokens"] += int(sum(min(r.max_new, w.bufs.shape[1])
                                        for r in w.requests))
        self.stats["cycles"] += w.cycles * len(w.requests)
        self.stats["wall_s"] += dt
        self.stats["waves"] += 1
        self.stats["alpha"] = (self._alpha_num / self._alpha_den
                               if self._alpha_den else 0.0)
        self.wave = None

    # ----------------------------------------------------- drain loop -----
    def run(self) -> Dict:
        while self.queue or self.wave is not None:
            if self.wave is None and not self.start_wave():
                break
            while self.step():
                pass
        s = dict(self.stats)
        s["tokens_per_s"] = (s["tokens"] / s["wall_s"]
                             if s["wall_s"] else 0.0)
        return s
