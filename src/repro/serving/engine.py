"""Batched D2SD serving engine: continuous slot-refill batching over a
pluggable KV storage layer.

Requests queue up and are served FIFO through a fixed-size batch of row
*slots* over one typed :class:`~repro.core.state.EngineState`:

* **Per-slot prefill** — each request is prefilled independently into its
  row via :func:`~repro.core.state.install_row` (a batch-1 prefill merged
  in with :meth:`EngineState.adopt_row` under a donated ``jit``, so the
  splice lowers to an in-place row write instead of a full-state copy),
  letting one running batch mix arbitrary prompt lengths AND arbitrary
  ``max_new`` budgets; there are no uniform-prompt-length waves.
* **Early-exit masking** — before every decode cycle the engine pushes a
  per-row ``active`` mask into the state; rows whose request already hit
  its budget (or whose slot is idle) draft a degenerate root-only tree and
  commit nothing, so they stop mutating KV / feature caches and stop
  polluting acceptance statistics (disable with ``early_exit=False``).
* **Slot refill** — the moment a request finishes, it retires into
  ``done`` and the FIFO head of the queue is prefilled into the vacated
  row, keeping the batch full under sustained traffic (disable with
  ``refill=False`` to get drain-the-wave batching for A/B comparison; see
  ``benchmarks/serving_bench.py``).

KV memory (``cache_impl``):

* ``dense`` — every slot reserves the worst-case ``max_len`` of the wave's
  candidate set for its whole lifetime.
* ``paged`` — a :class:`~repro.models.kvcache.PagePool` (engine-lifetime
  by default, see *Pool scope* below) backs the target global-attention
  KV and both drafter feature caches.
  **Admission accounts in pages**: a request needs
  ``ceil(cache_needed / page_size)`` pages and is adopted iff that many
  pages are free — not iff a dense ``max_len`` row is. **Retire frees its
  pages** back to the pool, and **slot refill is copy-free**: install
  allocates pages, prefills straight into them through a pool-sharing
  batch-1 view, and patches one page-table row (see
  :func:`~repro.core.state.row_template`). Per-request token output is
  identical across both impls (asserted by the serving bench).

Pool scope (``pool_scope``, paged only — the borrowed-pool contract):

* ``engine`` (default) — the engine allocates ONE :class:`PagePool` for
  its whole lifetime, sized once by the engine-global rule
  (:meth:`ServingEngine._pool_budget`: the worst-case *concurrent* live
  set plus ``pool_headroom`` × that for prefix retention, or an explicit
  ``pool_pages`` override). Waves are *borrowers*, not owners: each
  ``start_wave`` builds its page tables against the shared pool, the
  device pool buffers are captured at wave turnover and re-installed
  into the next wave's state (:func:`~repro.core.state.capture_pools` /
  :func:`~repro.core.state.adopt_pools`), and a new wave's initial set
  is capped to what the pool can grant (later arrivals wait for refill
  admission). Eviction pressure is engine-global: free pages plus the
  radix cache's evictable pages, regardless of which wave cached them.
* ``wave`` — legacy per-wave pools (allocated in ``start_wave``, dropped
  with the wave; every cached prefix dies at turnover). Kept as the A/B
  reference for the serving bench and parity tests.

Prefix cache (``prefix_cache=True``, paged only):

* a :class:`~repro.serving.prefix_cache.PrefixCache` — a radix tree over
  retired requests' committed token strings whose nodes own refcounted
  page runs in the pool. With the default engine-lifetime pool the tree
  OUTLIVES waves: wave N+1's prompts hit prefixes committed in wave N
  (the resident-server fast path; see ``--suite resident``). Admission
  matches each prompt against the tree; on a hit the matched prefix's
  full pages are spliced read-only into the new row's page table
  (refcount bumped) and only the uncached suffix is prefilled
  (``install_row(prefix_hit=...)`` — token-identical to a cold install).
  A match ending mid-page first copies the shared tail page to a fresh
  page (COW: a page with refcount > 1 is never written). Retiring a
  request inserts its committed prefix back into the tree (private pages
  donated); under pool pressure LRU unpinned leaves are evicted.
  Requires an all-global-attention target: sliding-window rolling
  buffers and recurrent states cannot be reconstructed from shared
  pages.

Prompt-length bucketing (``bucket_sizes``, default ``"auto"`` = the
pow-2 :data:`DEFAULT_BUCKETS` ladder; pass ``None`` for exact-length
installs): install prefills are padded to a small set of length buckets
(real length masked via ``true_len``), so the donated install jit
compiles O(buckets) instead of O(distinct prompt/suffix lengths) under
naturally varying traffic; ``install_traces`` in stats counts the
distinct shapes actually traced.

Cycle API (overlap contract): :meth:`ServingEngine.dispatch_cycle`
launches one decode cycle and returns immediately (JAX async dispatch);
:meth:`complete_cycle` blocks on its results, banks tokens, and retires —
the ONLY host/device sync boundary. Between the two, the host owns the
overlap window: :meth:`admit_idle` fills idle slots from the queue while
the device decodes, collapsing same-length-bucket admission groups into
single batched :func:`~repro.core.state.install_rows` dispatches; the
install's anchor token is never read back inline (pending-anchor
deferral, flushed at the next retire boundary). The synchronous
:meth:`step` is dispatch + complete back-to-back; the async front-end
(``serving/frontend.py``) drives the split form. Timestamps and
per-request SLA events go through an injected
:class:`~repro.serving.metrics.Clock` / ``MetricsRecorder``
(``serving/metrics.py``), shared by both drivers.
Aggregate stats track tokens actually committed per request
(``min(filled, max_new)``), acceptance ``alpha`` over *active* row-cycles
only and ``accepted`` draft tokens wired from the verify backends'
``n_acc``, ``wasted_row_cycles``, the KV-memory counters
(``refill_copy_bytes`` — accounting model of bytes written per install,
:func:`~repro.core.state.refill_copy_bytes` — plus ``pool_pages`` /
``pool_peak_pages`` and the per-cycle mean ``pool_utilization``), and the
prefix-cache counters (``prefix_hits`` / ``prefix_misses`` /
``prefix_hit_tokens`` / ``prefill_tokens_saved`` / ``cow_copies`` /
``prefix_evictions``).

Mesh residency (sharded resident serving): construct the engine inside a
``use_sharding(mesh, ...)`` context (``launch/serve.py --mesh-model`` /
``--kv-seq-axis``) and ONE engine spans the mesh. The invariant is
**page identity is global, page bytes are per-shard**: every host-side
structure above — allocator free list, refcounts, radix tree, page
tables, admission accounting, ``_pool_budget`` — is unchanged and counts
GLOBAL pages, while each page's payload bytes are laid out along the
``kv_seq`` mesh axis (``page_size // kv_shards`` slots of every page per
shard; :func:`~repro.models.kvcache.shard_pool`). Decode's paged
cascade verify runs under ``shard_map`` with the per-shard cache
contribution merged by one float32 LSE ``psum``
(:func:`~repro.distributed.spdecode.sharded_paged_cache_attend`), so
per-request tokens are identical to the single-device engine (asserted
by ``tests/test_sharded_serving.py`` and ``--suite sharded``). The
borrowed-pool contract is shard-preserving: :func:`capture_pools` /
``engine_init(pools=...)`` hand the SAME device buffers (and hence
their kv_seq layout) across wave turnover, zero-copy. The engine
captures the construction-time mesh context and re-enters it around
every device-facing call (the context is threadlocal and the async
front-end drives the engine from a worker thread), and threads
``sharding.mesh_tag()`` as a static cache-splitter into every jit so
sharded and unsharded engines coexist in one process. Stats gain
``kv_shards``, ``pool_shard_slots`` (per-shard slot capacity:
``pool_pages * page_size / kv_shards``) and ``decode_collective_bytes``
(accounting model of the bytes the verify psum moves per decode cycle).
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pipeline as pl
from repro.core.state import (EngineState, capture_pools, cow_copy_page,
                              install_row, install_rows, refill_copy_bytes)
from repro.distributed import sharding as sh
from repro.distributed import spdecode
from repro.models import kvcache as kvc
from repro.serving.metrics import Clock, MetricsRecorder, MonotonicClock
from repro.serving.prefix_cache import PrefixCache, PrefixHit


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # [P]
    max_new: int
    out: Optional[np.ndarray] = None
    n_cycles: int = 0
    latency_s: float = 0.0
    t_start: float = 0.0


@dataclasses.dataclass
class Wave:
    """One running batch: typed engine state + per-slot request books."""
    requests: List[Optional[Request]]   # slot -> live request (None = idle)
    state: EngineState
    bufs: np.ndarray            # [B, cap] committed tokens (slot 0 = anchor)
    filled: np.ndarray          # [B] tokens committed so far
    targets: np.ndarray         # [B] per-request max_new (0 for idle slots)
    t0: float
    cycles: int = 0
    pool: Optional[kvc.PagePool] = None        # paged mode (BORROWED when
    #                                            pool_scope="engine")
    row_pages: Optional[List[List[int]]] = None  # slot -> PRIVATE pages
    cache: Optional[PrefixCache] = None        # prefix_cache=True only
    row_tables: Optional[List[Optional[np.ndarray]]] = None  # host copies
    row_hits: Optional[List[Optional[PrefixHit]]] = None
    trunc: Optional[np.ndarray] = None  # [B] output buf overflowed (bool)
    evictions0: int = 0                 # cache.evictions at wave start
    # slots whose install-produced anchor token has not been read back to
    # bufs yet — materialized lazily at the next safe host-sync boundary
    # (_flush_anchors), so an overlapped install never forces a device sync
    pending_anchor: Set[int] = dataclasses.field(default_factory=set)

    @property
    def done(self) -> bool:
        return all(r is None for r in self.requests)


#: default install-prefill length buckets (pow-2 ladder; longer prompts
#: round up to a multiple of the largest bucket)
DEFAULT_BUCKETS = (8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096)


class ServingEngine:
    def __init__(self, bundle: pl.SpecBundle, batch_size: int = 8,
                 seed: int = 0, early_exit: bool = True,
                 refill: bool = True, cache_impl: str = "dense",
                 page_size: int = 64, prefix_cache: bool = False,
                 bucket_sizes="auto", pool_scope: str = "engine",
                 pool_pages: Optional[int] = None,
                 pool_headroom: float = 1.0,
                 clock: Optional[Clock] = None,
                 recorder: Optional[MetricsRecorder] = None):
        assert cache_impl in ("dense", "paged"), cache_impl
        assert pool_scope in ("engine", "wave"), pool_scope
        if pool_pages is not None and not (cache_impl == "paged"
                                           and pool_scope == "engine"):
            raise ValueError(
                "pool_pages only sizes the engine-lifetime pool "
                "(cache_impl='paged', pool_scope='engine'); per-wave "
                "pools are sized per wave by the engine-global rule")
        if prefix_cache:
            if cache_impl != "paged":
                raise ValueError(
                    "prefix_cache=True requires cache_impl='paged': "
                    "cross-request sharing is a page-table splice")
            kinds = set(bundle.target_cfg.pattern_for_depth())
            if kinds != {"global"}:
                raise ValueError(
                    "prefix_cache=True requires an all-global-attention "
                    "target: sliding-window rolling buffers and recurrent "
                    f"states cannot be rebuilt from shared pages ({kinds})")
        if cache_impl == "paged" and not early_exit:
            # a retired slot's pages return to the pool but its stale page
            # table survives until refill; without early-exit masking the
            # idle row would keep committing KV through that table into
            # pages the allocator may have granted to a live request —
            # silent cross-request corruption. The legacy all-rows-run
            # configuration exists only for dense A/B benchmarking.
            raise ValueError(
                "cache_impl='paged' requires early_exit=True: idle slots "
                "must be masked so they cannot write through stale page "
                "tables into freed (reallocated) pages")
        # mesh residency: capture the ambient sharding context ONCE at
        # construction. One engine spans the whole mesh — pool payloads
        # are laid out along the kv_seq axis (page bytes per-shard, page
        # IDENTITY global: the host allocator / radix tree / page tables
        # below never see the mesh). Every device-facing call site
        # re-enters the context via _mesh_scope so the engine keeps
        # working when driven from another thread (the async front-end's
        # worker: sharding._CTX is threadlocal).
        self._mesh = sh.active_mesh()
        self._rules = dict(sh._CTX.rules) if self._mesh is not None else None
        self._fsdp = sh.fsdp_enabled()
        self._shard_tag = sh.mesh_tag()
        self.kv_shards = spdecode.kv_seq_shards()
        if cache_impl == "paged" and page_size % self.kv_shards != 0:
            raise ValueError(
                f"page_size={page_size} must be divisible by the kv_seq "
                f"mesh axis size ({self.kv_shards}): page payloads are "
                f"split WITHIN the page — each shard owns "
                f"page_size // n_shards slots of every page")
        self.bundle = bundle
        self.batch_size = batch_size
        self.early_exit = early_exit
        self.refill = refill
        self.cache_impl = cache_impl
        self.page_size = page_size
        self.prefix_cache = prefix_cache
        self.pool_scope = pool_scope
        self._pool_pages_cfg = pool_pages
        self.pool_headroom = float(pool_headroom)
        # engine-lifetime pool + radix tree (paged, pool_scope="engine"):
        # created at the first start_wave, borrowed by every wave after
        self.pool: Optional[kvc.PagePool] = None
        self.cache: Optional[PrefixCache] = None
        self._pools = None      # device pool buffers retained between waves
        # "auto" -> the pow-2 ladder; None / () -> exact-length installs
        # (one donated-install trace per distinct prompt/suffix length)
        if bucket_sizes == "auto":
            bucket_sizes = DEFAULT_BUCKETS
        self.bucket_sizes = (tuple(sorted(bucket_sizes))
                             if bucket_sizes else None)
        # every engine timestamp goes through the injected clock (the sync
        # drain loop and the async front-end share one timing source, so
        # their wall_s / SLA numbers are directly comparable); the engine
        # also charges simulated work to it (tick "cycle" per dispatched
        # decode cycle, "install" per install dispatch) — a no-op on the
        # real MonotonicClock, deterministic cost on a VirtualClock
        self.clock = clock if clock is not None else MonotonicClock()
        self.recorder = recorder
        self.queue: List[Request] = []
        self.done: List[Request] = []
        self.key = jax.random.PRNGKey(seed)
        self._next_uid = 0
        self.wave: Optional[Wave] = None
        # shares pipeline's module-level trace cache across engine
        # instances; shard_tag splits that cache between sharded and
        # unsharded engines living in one process (jit keys on avals,
        # not on the threadlocal mesh context the trace reads)
        self._cycle = lambda s, k: pl._cycle_jit(self.bundle, s, k,
                                                 collect_stats=False,
                                                 shard_tag=self._shard_tag)
        self.stats = {"tokens": 0, "cycles": 0, "accepted": 0,
                      "wall_s": 0.0, "waves": 0, "alpha": 0.0,
                      "wasted_row_cycles": 0, "refills": 0,
                      "refill_copy_bytes": 0, "installs": 0,
                      "install_traces": 0, "install_calls": 0,
                      "pool_pages": 0, "pool_peak_pages": 0,
                      "pool_utilization": 0.0,
                      "prefix_hits": 0, "prefix_misses": 0,
                      "prefix_hit_tokens": 0, "prefill_tokens_saved": 0,
                      "cow_copies": 0, "prefix_evictions": 0,
                      "prefix_cached_pages": 0,
                      "kv_shards": self.kv_shards,
                      "pool_shard_slots": 0,
                      "decode_collective_bytes": 0,
                      "warm_cycle_s": 0.0, "warm_cycles": 0}
        self._alpha_num = 0
        self._alpha_den = 0
        self._util_sum = 0.0
        self._util_samples = 0
        # steady-state per-cycle wall durations: dispatch->complete deltas
        # of every cycle EXCEPT each wave's first (trace/compile-dominated
        # at tiny scale — wall_s keeps the all-in number, warm_cycle_s is
        # the median of these)
        self._warm_durs: List[float] = []
        self._install_shapes = set()
        # per-cycle decode-collective payload (bytes moved by the verify
        # LSE psum per cycle), learned from the first fresh decode trace
        self._cycle_payload = 0

    @contextlib.contextmanager
    def _mesh_scope(self):
        """Re-enter the construction-time sharding context around a
        device-facing call. The context is threadlocal; the async
        front-end drives the engine from a worker thread that never saw
        the caller's ``use_sharding`` block."""
        if self._mesh is None:
            yield
        else:
            with sh.use_sharding(self._mesh, self._rules, fsdp=self._fsdp):
                yield

    def submit(self, prompt: np.ndarray, max_new: int,
               t_arrival: Optional[float] = None) -> int:
        # Monotonic uid: len(queue)+len(done) would collide once a wave
        # drains the queue mid-run.
        uid = self._next_uid
        self._next_uid += 1
        self.queue.append(Request(uid, np.asarray(prompt, np.int32),
                                  max_new))
        if self.recorder is not None:
            # open-loop drivers pass the trace arrival time so TTFT counts
            # from when the client sent the request, not from this call
            self.recorder.on_arrival(uid, t=t_arrival)
        return uid

    def _next_wave(self) -> List[Request]:
        # FIFO: the wave anchors on the oldest queued request. (Re-sorting
        # by prompt length let sustained short-prompt traffic starve an
        # early long-prompt request forever; per-slot prefill removed the
        # uniform-length constraint that motivated the sort.)
        take = self.queue[: self.batch_size]
        if self.pool is not None and take:
            # engine-lifetime pool: a NEW wave's initial set must fit the
            # fixed pool even after the radix tree gives back everything
            # it can — requests beyond the budget stay queued and enter
            # through refill admission (_fits) instead. Between waves
            # nothing is pinned, so the budget is the whole pool.
            g = self.bundle.spec.gamma
            budget = self.pool.free_pages + (
                self.cache.evictable_pages() if self.cache is not None
                else 0)
            kept: List[Request] = []
            acc = 0
            for r in take:
                n = self._pages_needed(r, g)
                if acc + n > budget:
                    break
                kept.append(r)
                acc += n
            if not kept:
                raise RuntimeError(
                    f"request uid={take[0].uid} needs "
                    f"{self._pages_needed(take[0], g)} pages but the "
                    f"engine-lifetime pool can grant at most {budget} of "
                    f"{self.pool.n_pages}; raise pool_pages / "
                    f"pool_headroom (or use pool_scope='wave')")
            take = kept
        self.queue = self.queue[len(take):]
        return take

    def _pool_budget(self, need: List[int], b: int) -> int:
        """Engine-global pool sizing rule (the single source of truth for
        BOTH pool scopes): the worst-case *concurrent* live set — the
        ``b`` largest candidate page needs — plus ``pool_headroom`` × that
        for prefix retention when the radix cache is on. Refill candidates
        are deliberately NOT summed in: they run in slots the live set
        vacates, so counting their full needs on top of the live set (the
        old ``sum(need)`` rule) double-counted them; only their retired
        prefixes — bounded by the headroom — need extra pages.

        Mesh residency: the budget counts GLOBAL pages — one allocation
        decision, P-way placement. Each page's payload bytes are split
        along the ``kv_seq`` mesh axis (``page_size // kv_shards`` slots
        of every page per shard), so the per-device budget this global
        count implies is ``pool bytes / kv_shards``; ``pool_shard_slots``
        in :attr:`stats` reports the per-shard slot capacity directly.
        Page identity (allocator, refcounts, radix tree, page tables)
        never shards."""
        live = sum(need[:b])
        if not self.prefix_cache:
            return live
        return live + int(np.ceil(self.pool_headroom * live))

    # ------------------------------------------------------ step API ------
    def start_wave(self, width: Optional[int] = None) -> bool:
        """Allocate + prefill the next running batch. False if queue empty.

        ``width`` (open-loop serving): build the wave with this many rows
        even if fewer requests are visible right now — the extra rows
        start idle (masked, sentinel page tables) and are filled later by
        refills / :meth:`admit_idle`. Without it the wave is exactly as
        wide as the initial batch, which is right for drain-loop replay
        (everything submitted up front) but starves an open-loop server:
        a wave started at the first arrival would be 1 row wide and
        chain-refill would keep that single row busy forever."""
        assert self.wave is None, "finish the active wave first"
        g = self.bundle.spec.gamma
        if (self.cache_impl == "paged" and self.pool_scope == "engine"
                and self.pool is None and self.queue):
            # allocate the engine-lifetime pool ONCE (explicit pool_pages
            # override, or the engine-global rule over the WHOLE visible
            # queue — the b largest needs anywhere in it, so a large
            # request submitted behind small ones still fits when its
            # turn comes); every later wave borrows the pool, so cached
            # prefixes survive turnover. Only a request larger than
            # anything visible at sizing time can fail admission later
            # (_next_wave raises with guidance).
            need0 = sorted((self._pages_needed(r, g) for r in self.queue),
                           reverse=True)
            b0 = min(self.batch_size, len(self.queue))
            n_pages = (self._pool_pages_cfg
                       if self._pool_pages_cfg is not None
                       else self._pool_budget(need0, b0))
            self.pool = kvc.PagePool(n_pages, self.page_size)
            if self.prefix_cache:
                self.cache = PrefixCache(self.pool)
        reqs = self._next_wave()
        if not reqs:
            return False
        b = (len(reqs) if width is None
             else min(self.batch_size, max(width, len(reqs))))
        # size caches for the wave plus the next batch of likely refill
        # candidates — not the whole queue, or one huge queued request
        # would inflate every slot's KV/feature allocation; requests that
        # don't fit simply wait for the next wave (see _fits)
        cand = reqs + self.queue[: self.batch_size]
        cap = max(self._bufs_needed(r, g) for r in cand)
        pool = None
        row_pages = None
        cache = None
        if self.cache_impl == "paged":
            # page-granular sizing: the table is as wide as the largest
            # candidate needs (capped at the pool — no row can ever hold
            # more), while the POOL is sized by _pool_budget: worst-case
            # concurrent set + prefix-retention headroom, never a per-
            # candidate sum. Engine scope reuses the engine pool; wave
            # scope (legacy A/B reference) builds a fresh one per wave.
            need = sorted((self._pages_needed(r, g) for r in cand),
                          reverse=True)
            if self.pool_scope == "engine":
                pool, cache = self.pool, self.cache
            else:
                pool = kvc.PagePool(self._pool_budget(need, b),
                                    self.page_size)
                if self.prefix_cache:
                    cache = PrefixCache(pool)
            pool_pages = pool.n_pages
            mp = min(need[0], pool_pages)
            row_pages = [[] for _ in range(b)]
            # all rows start unallocated: table rows hold the growth-stable
            # sentinel until _install patches them
            table = np.full((b, mp), kvc.PAGE_SENTINEL, np.int32)
            # borrowed-pool contract: retained device pool buffers (from
            # capture_pools at the last turnover) go straight into init —
            # pages the radix tree kept hold their KV across the turnover
            # and the transient pool-sized zero allocation the old
            # init-then-adopt_pools sequence paid is never materialized.
            # Drop our reference: the wave's first donated install
            # consumes the state. engine_init runs under the mesh scope:
            # fresh pool buffers are device_put along kv_seq at birth
            # (adopted buffers pass through untouched — zero-copy).
            with self._mesh_scope():
                state = pl.engine_init(self.bundle, b, mp * self.page_size,
                                       cache_impl="paged",
                                       page_size=self.page_size,
                                       pool_pages=pool_pages,
                                       page_table=table,
                                       pools=self._pools)
            self._pools = None
            # lifetime max, matching pool_peak_pages' scope — a small
            # leftover wave must not shrink the reported pool below the
            # peak measured in an earlier, larger wave
            self.stats["pool_pages"] = max(self.stats["pool_pages"],
                                           pool_pages)
            self.stats["pool_shard_slots"] = max(
                self.stats["pool_shard_slots"],
                pool_pages * (self.page_size // self.kv_shards))
        else:
            max_len = max(self._cache_needed(r, g) for r in cand)
            with self._mesh_scope():
                state = pl.engine_init(self.bundle, b, max_len)
        state = state.replace(active=jnp.zeros((b,), bool))
        self.wave = Wave(requests=[None] * b, state=state,
                         bufs=np.zeros((b, cap), np.int32),
                         filled=np.zeros((b,), np.int64),
                         targets=np.zeros((b,), np.int64),
                         t0=self.clock.now(), pool=pool,
                         row_pages=row_pages,
                         cache=cache, row_tables=[None] * b,
                         row_hits=[None] * b, trunc=np.zeros((b,), bool),
                         evictions0=cache.evictions if cache else 0)
        # two passes: install EVERY initial request before the first retire.
        # A retire can chain-refill from beyond the pool-sizing candidate
        # window; interleaving it with the initial installs could hand those
        # refills pages the pool only guarantees for the initial set.
        # Same-bucket initial installs collapse into batched install_rows
        # calls (one dispatch + one batch-K prefill per length group).
        self._install_group(list(enumerate(reqs)))
        for i in range(b):
            if (self.wave.requests[i] is not None
                    and self.wave.filled[i] >= self.wave.targets[i]):
                # satisfied by the prefill alone (max_new <= 1): retire
                # (and possibly refill) without paying a decode cycle
                self._retire(i)
        if self.wave.done:
            self._finish_wave()
        return True

    def _bucket(self, n: int) -> int:
        """Pad a prefill length to its bucket (identity when disabled)."""
        if self.bucket_sizes is None:
            return n
        for b in self.bucket_sizes:
            if b >= n:
                return b
        top = self.bucket_sizes[-1]
        return -(-n // top) * top

    def _prep_install(self, slot: int, r: Request) -> int:
        """Host-side admission work for one install: prefix-cache match,
        page allocation, table splice, COW. Returns the matched prefix
        length (0 = cold install; dense mode is always 0).

        Split from the device dispatch so :meth:`_install_group` can prep
        a whole admission group FIRST (in pick order — each lookup sees
        the radix tree exactly as the previous prep left it) and then
        batch the dispatches by the ACTUAL outcome (suffix bucket ×
        warm/cold), prefix hits included."""
        w = self.wave
        if self.cache_impl != "paged":
            return 0
        prompt = np.asarray(r.prompt, np.int32)
        g = self.bundle.spec.gamma
        n_total = self._pages_needed(r, g)
        hit = w.cache.lookup(prompt) if w.cache is not None else None
        if hit is not None:
            w.cache.acquire(hit)        # pin shared pages + COW source
        n_new = n_total - (len(hit.shared) if hit else 0)
        if w.pool.free_pages < n_new and w.cache is not None:
            w.cache.evict_for(n_new)
        pages = w.pool.alloc(n_new)
        if pages is None and hit is not None:
            # tight pool: the admission guarantee (_fits) is for the
            # miss shape — give the hit back and install cold
            w.cache.release_partial(hit)
            w.cache.release(hit)
            hit = None
            w.cache.evict_for(n_total)
            pages = w.pool.alloc(n_total)
        assert pages is not None, "admission control must guarantee pages"
        w.row_pages[slot] = pages
        shared = hit.shared if hit else []
        w.row_tables[slot] = w.pool.row_table(shared + pages,
                                              w.state.max_pages)
        if hit is not None:
            if hit.partial is not None:
                # COW: duplicate the shared partial tail page into the
                # row's first private page BEFORE any write lands there
                # (a page with refcount > 1 is never written)
                w.state = cow_copy_page(w.state, hit.partial, pages[0])
                self.stats["cow_copies"] += 1
            w.cache.release_partial(hit)
            self.stats["prefix_hits"] += 1
            self.stats["prefix_hit_tokens"] += hit.length
            # tokens the suffix prefill actually skips relative to a
            # cold install — measured in BUCKETED lengths, so padding
            # that a cold install would have paid anyway counts as
            # saved and padding the suffix re-pays is deducted
            self.stats["prefill_tokens_saved"] += (
                self._bucket(len(prompt))
                - self._bucket(len(prompt) - hit.length))
        elif w.cache is not None:
            self.stats["prefix_misses"] += 1
        w.row_hits[slot] = hit
        return hit.length if hit else 0

    def _install(self, slot: int, r: Request,
                 prefix_len: Optional[int] = None) -> None:
        """Prefill ``r`` into ``slot`` of the running batch (slot refill).

        The donated :func:`install_row` consumes the old wave state, so
        the splice / page writes happen in place — no full-state copy in
        either impl. Paged mode additionally allocates the request's
        pages via :meth:`_prep_install` (freed again by :meth:`_retire`);
        with the prefix cache on, the prompt is first matched against the
        radix tree: the matched prefix's full pages are spliced read-only
        into the row's table, a mid-page match tail is COW-copied, and
        only the uncached suffix is prefilled. ``prefix_len`` short-
        circuits the prep when :meth:`_install_group` already ran it.
        """
        w = self.wave
        self.key, sub = jax.random.split(self.key)
        if prefix_len is None:
            prefix_len = self._prep_install(slot, r)
        hit = w.row_hits[slot] if w.row_hits is not None else None
        row_table = (w.row_tables[slot] if self.cache_impl == "paged"
                     else None)
        prompt = np.asarray(r.prompt, np.int32)
        suffix = prompt[prefix_len:]
        s = len(suffix)
        true_len = None
        if self.bucket_sizes is not None:
            pad = self._bucket(s)
            suffix = np.concatenate(
                [suffix, np.zeros((pad - s,), np.int32)])
            true_len = s
        # full donated-install trace key: suffix shape + warm/cold + the
        # wave geometry the state shapes derive from (a new wave with a
        # different batch / capacity / pool size retraces even for an
        # already-seen suffix length)
        self._install_shapes.add(
            (1, len(suffix), hit is not None, w.state.batch, w.state.max_len,
             w.pool.n_pages if w.pool is not None else 0))
        self.stats["install_traces"] = len(self._install_shapes)
        self.stats["refill_copy_bytes"] += refill_copy_bytes(w.state, s)
        self.stats["installs"] += 1
        self.stats["install_calls"] += 1
        if self.recorder is not None:
            self.recorder.on_admit(r.uid)
        with self._mesh_scope():
            w.state = install_row(self.bundle, w.state, slot, suffix,
                                  key=sub,
                                  temperature=self.bundle.spec.temperature,
                                  row_table=row_table,
                                  prefix_hit=prefix_len if hit else None,
                                  true_len=true_len,
                                  shard_tag=self._shard_tag)
        self.clock.tick("install")
        self._book_install(slot, r)

    def _book_install(self, slot: int, r: Request) -> None:
        """Host bookkeeping shared by single and batched installs. The
        anchor token (the request's FIRST generated token, produced by the
        install's prefill) is NOT read back here — reading it would block
        the host on the device stream and kill install/decode overlap.
        The slot is marked pending and the anchor lands in ``bufs`` at the
        next retire boundary (:meth:`_flush_anchors`)."""
        w = self.wave
        w.bufs[slot] = 0
        w.pending_anchor.add(slot)
        w.filled[slot] = 1
        w.targets[slot] = r.max_new
        w.requests[slot] = r
        w.trunc[slot] = False
        r.t_start = self.clock.now()
        r.n_cycles = 0
        if self.recorder is not None:
            # first token exists once the dispatched install completes —
            # stamped here at dispatch, after charging the install tick
            self.recorder.on_first_token(r.uid)

    def _flush_anchors(self) -> None:
        """Materialize pending install anchors into ``bufs``.

        The single deferred host read of the overlap design: called before
        a cycle dispatch consumes (donates) the state, and at retire
        boundaries before banked outputs are assembled. One blocking
        ``np.asarray`` covers every install since the last flush."""
        w = self.wave
        if w is None or not w.pending_anchor:
            return
        anchors = np.asarray(w.state.anchor)
        for slot in w.pending_anchor:
            w.bufs[slot, 0] = int(anchors[slot])
        w.pending_anchor.clear()

    def _install_group(self, picks: List[Tuple[int, Request]]) -> None:
        """Install (slot, request) picks, collapsing same-suffix-bucket
        groups into ONE batched :func:`install_rows` dispatch each —
        prefix-cache hits included.

        The batched path requires greedy anchors (temperature 0: argmax
        is key-independent, so one shared PRNG key is token-identical to
        per-request keys); sampling picks fall back to the single-slot
        :meth:`_install`. With the radix cache on, all host-side prep
        (lookup / page alloc / COW splice) runs FIRST in pick order —
        each lookup sees the tree exactly as the previous prep left it,
        so an earlier pick's eviction can't invalidate a later pick's
        planned group — then picks group by their ACTUAL outcome:
        (suffix bucket, warm/cold). Warm rows with different prefix
        lengths share one batch (``install_rows(prefix_hits=[K])`` takes
        a per-row start vector); mixed warm/cold groups are disallowed
        by the state layer, hence the cold/warm key split.
        """
        if self.bundle.spec.temperature > 0 or len(picks) <= 1:
            for slot, r in picks:
                self._install(slot, r)
            return
        prepped = [(slot, r, self._prep_install(slot, r))
                   for slot, r in picks]
        groups: Dict[Tuple[int, bool],
                     List[Tuple[int, Request, int]]] = {}
        for slot, r, pfx in prepped:
            key = (self._bucket(len(r.prompt) - pfx), pfx > 0)
            groups.setdefault(key, []).append((slot, r, pfx))
        for (pad, warm), grp in sorted(groups.items()):
            if len(grp) == 1:
                slot, r, pfx = grp[0]
                self._install(slot, r, prefix_len=pfx)
            else:
                self._install_batch(grp, pad, warm)

    def _install_batch(self, grp: List[Tuple[int, Request, int]], pad: int,
                       warm: bool = False) -> None:
        """One donated batch-K install for K same-suffix-bucket requests
        (already prepped by :meth:`_prep_install`; all cold or all warm —
        warm rows may carry different prefix lengths)."""
        w = self.wave
        self.key, sub = jax.random.split(self.key)
        k = len(grp)
        row_tables = None
        if self.cache_impl == "paged":
            row_tables = np.stack([w.row_tables[slot]
                                   for slot, _, _ in grp])
        prompts = np.zeros((k, pad), np.int32)
        true = np.zeros((k,), np.int32)
        pfx = np.zeros((k,), np.int32)
        for i, (slot, r, p0) in enumerate(grp):
            sfx = np.asarray(r.prompt, np.int32)[p0:]
            prompts[i, : len(sfx)] = sfx
            true[i] = len(sfx)
            pfx[i] = p0
            self.stats["refill_copy_bytes"] += refill_copy_bytes(
                w.state, len(sfx))
            if self.recorder is not None:
                self.recorder.on_admit(r.uid)
        self._install_shapes.add(
            (k, pad, warm, w.state.batch, w.state.max_len,
             w.pool.n_pages if w.pool is not None else 0))
        self.stats["install_traces"] = len(self._install_shapes)
        self.stats["installs"] += k
        self.stats["install_calls"] += 1
        true_len = true if self.bucket_sizes is not None else None
        with self._mesh_scope():
            w.state = install_rows(self.bundle, w.state,
                                   np.array([s for s, _, _ in grp],
                                            np.int32),
                                   prompts, key=sub,
                                   temperature=self.bundle.spec.temperature,
                                   row_tables=row_tables, true_len=true_len,
                                   prefix_hits=pfx if warm else None,
                                   shard_tag=self._shard_tag)
        # ONE dispatch for the whole group: one simulated install charge
        self.clock.tick("install")
        for slot, r, _ in grp:
            self._book_install(slot, r)

    # ---- sizing: single source of truth for allocation and admission ----
    @staticmethod
    def _bufs_needed(r: Request, g: int) -> int:
        """Output-buffer slots: budget + worst-case overshoot + anchor."""
        return r.max_new + g + 1

    @staticmethod
    def _cache_needed(r: Request, g: int) -> int:
        """KV/feature-cache positions: prompt + budget + draft headroom
        (the same sizing rule as ``generate``'s default max_len)."""
        return len(r.prompt) + r.max_new + 2 * g + 8

    def _pages_needed(self, r: Request, g: int) -> int:
        return kvc.pages_for(self._cache_needed(r, g), self.page_size)

    def _fits(self, r: Request, reserved_pages: int = 0) -> bool:
        """Can ``r`` be adopted into the current wave's allocation?
        Paged mode admits on free *pages*, not a per-slot max_len row;
        with the prefix cache on, LRU-evictable (unpinned) cached pages
        count as available — the check is deliberately for the MISS
        shape, so an install can always fall back to cold if the pool is
        too tight to honor its hit. ``reserved_pages``: pages already
        promised to co-admitted requests whose installs have not
        allocated yet (admit_idle picks a group before installing it)."""
        w = self.wave
        g = self.bundle.spec.gamma
        if self._bufs_needed(r, g) > w.bufs.shape[1]:
            return False
        if self.cache_impl == "paged":
            n = self._pages_needed(r, g)
            avail = w.pool.free_pages - reserved_pages
            if w.cache is not None:
                avail += w.cache.evictable_pages()
            return n <= w.state.max_pages and n <= avail
        return self._cache_needed(r, g) <= w.state.max_len

    def _host_active(self) -> np.ndarray:
        """[B] rows holding a request that still wants tokens."""
        w = self.wave
        return np.array([r is not None and w.filled[i] < w.targets[i]
                         for i, r in enumerate(w.requests)])

    def dispatch_cycle(self):
        """Launch ONE decode cycle on device WITHOUT waiting for it.

        Returns an opaque handle for :meth:`complete_cycle` (None when no
        wave is running). JAX async dispatch means the call returns as
        soon as the cycle is enqueued; the host is then free to do
        admission work — match queued prompts, allocate pages, dispatch
        installs for idle slots (:meth:`admit_idle`) — while the device
        decodes. Pending install anchors are flushed FIRST: the cycle
        donates (invalidates) the state they live in.
        """
        w = self.wave
        if w is None:
            return None
        self._flush_anchors()
        b = len(w.requests)
        active = self._host_active()
        # push the mask: with early_exit, finished/idle rows cost nothing
        # and commit nothing; without it they keep running full cycles
        # (legacy behavior, kept for A/B benchmarking)
        w.state = w.state.replace(
            active=jnp.asarray(active) if self.early_exit
            else jnp.ones((b,), bool))
        self.key, sub = jax.random.split(self.key)
        n0 = len(spdecode.PAYLOAD_TRACE)
        with self._mesh_scope():
            w.state, out = self._cycle(w.state, sub)
        if len(spdecode.PAYLOAD_TRACE) > n0:
            # a fresh decode trace under a mesh just recorded the bytes
            # its verify LSE-merge collectives move per cycle (one entry
            # per sharded paged-attend layer); bank the per-cycle sum
            self._cycle_payload = sum(spdecode.PAYLOAD_TRACE[n0:])
        self.stats["decode_collective_bytes"] += self._cycle_payload
        w.cycles += 1
        self.clock.tick("cycle")
        if w.pool is not None:
            self._util_sum += w.pool.pages_in_use / max(w.pool.n_pages, 1)
            self._util_samples += 1
        # stats: only rows that were actively serving a request count
        # toward acceptance; the rest are wasted batch capacity
        self.stats["wasted_row_cycles"] += int(b - active.sum())
        return active, out, self.clock.now()

    def complete_cycle(self, handle) -> bool:
        """Block on a dispatched cycle's results, bank tokens, retire.

        The ``np.asarray`` reads below are the wave's ONLY device-sync
        boundary: everything dispatched since the handle was created (the
        cycle itself plus any overlapped installs) completes before the
        banked streams are touched. Returns True while any slot still has
        an unfinished request; False once the wave has closed — including
        the case where ``start_wave`` already finished it outright (a
        burst of ``max_new <= 1`` requests satisfied by their prefills).
        """
        w = self.wave
        if handle is None or w is None:
            return False
        active, out, t_disp = handle
        toks = np.asarray(out["tokens"])            # retire-boundary sync
        n_out = np.asarray(out["n_out"])
        if w.cycles > 1:
            # steady-state sample: the wave's first cycle carries the
            # trace/compile cost and is excluded (wall_s still counts it)
            self._warm_durs.append(self.clock.now() - t_disp)
        cap = w.bufs.shape[1]
        self._alpha_num += int(n_out[active].sum())
        self._alpha_den += int(active.sum())
        # real accepted-draft counts straight from the verify backends
        self.stats["accepted"] += int(np.asarray(out["n_acc"])[active].sum())
        for i in range(len(w.requests)):
            r = w.requests[i]
            if r is None:
                continue
            if active[i]:
                m = min(int(n_out[i]), cap - int(w.filled[i]))
                if m > 0:
                    w.bufs[i, w.filled[i]: w.filled[i] + m] = toks[i, :m]
                if m < int(n_out[i]):
                    # committed tokens fell off the output buffer: the
                    # banked stream no longer mirrors the cache contents,
                    # so this row must not seed the prefix tree
                    w.trunc[i] = True
                w.filled[i] = min(w.filled[i] + int(n_out[i]), cap)
                r.n_cycles += 1
            if w.filled[i] >= w.targets[i] or r.n_cycles > r.max_new + 8:
                self._retire(i)
        if w.done:
            self._finish_wave()
            return False
        return True

    def step(self) -> bool:
        """Run ONE decode cycle synchronously (dispatch + complete
        back-to-back) and bank its tokens. Finished requests retire
        immediately and (with ``refill``) their slot adopts the FIFO head
        of the queue via a per-slot prefill."""
        return self.complete_cycle(self.dispatch_cycle())

    def admit_idle(self) -> int:
        """Mid-flight admission: fill IDLE slots from the queue while a
        dispatched cycle is still decoding on device (the overlap window).

        The synchronous engine refills only at the retire moment — a slot
        that goes idle because the queue happened to be empty right then
        stays idle until the wave ends. Called between
        :meth:`dispatch_cycle` and :meth:`complete_cycle`, this admits
        bursty arrivals that landed since: the host groups same-bucket
        prompts, allocates their pages, and dispatches batched installs
        (:func:`~repro.core.state.install_rows`) that the device executes
        after the in-flight cycle — idle slots start producing one cycle
        later instead of one WAVE later. Safe without a sync because an
        idle slot is inactive in the running cycle (mask snapshot taken
        at dispatch) and installs touch only that row + freshly allocated
        pages. Returns the number of requests admitted.
        """
        w = self.wave
        if w is None or not self.refill or not self.queue:
            return 0
        g = self.bundle.spec.gamma
        picks: List[Tuple[int, Request]] = []
        reserved = 0
        for slot in range(len(w.requests)):
            if w.requests[slot] is not None:
                continue
            if not self.queue or not self._fits(self.queue[0], reserved):
                break
            r = self.queue.pop(0)
            picks.append((slot, r))
            if self.cache_impl == "paged":
                # reserve against concurrent picks: _fits sees the pool
                # before these installs allocate their pages
                reserved += self._pages_needed(r, g)
        if not picks:
            return 0
        self._install_group(picks)
        self.stats["refills"] += len(picks)
        for slot, r in picks:
            if w.requests[slot] is not None \
                    and w.filled[slot] >= w.targets[slot]:
                # satisfied by the prefill alone (max_new <= 1)
                self._retire(slot)
        return len(picks)

    def _retire(self, slot: int) -> None:
        w = self.wave
        while True:
            # retire boundary: the banked stream (incl. any pending install
            # anchor — a chain-refilled max_new<=1 request retires straight
            # from its prefill) must be materialized before r.out is cut
            self._flush_anchors()
            r = w.requests[slot]
            r.out = w.bufs[slot, : r.max_new].copy()
            r.latency_s = self.clock.now() - r.t_start
            self.done.append(r)
            # count tokens actually committed: a cycle-cap bailout can
            # retire a request with filled < max_new, which must not
            # inflate tokens_per_s
            committed = int(min(w.filled[slot], r.max_new))
            self.stats["tokens"] += committed
            if self.recorder is not None:
                self.recorder.on_done(r.uid, committed)
            w.requests[slot] = None
            w.targets[slot] = 0
            if w.pool is not None:
                donated = set()
                if w.cache is not None and not w.trunc[slot]:
                    # seed the radix tree with this request's committed
                    # string (prompt + every banked token except the last
                    # anchor, which was never written to cache); private
                    # pages covering the new suffix are DONATED to the
                    # tree, the rest are freed below
                    committed = np.concatenate(
                        [np.asarray(r.prompt, np.int32),
                         w.bufs[slot, : max(int(w.filled[slot]) - 1, 0)]])
                    hit = w.row_hits[slot]
                    donated = w.cache.insert(
                        committed, w.row_tables[slot],
                        private=set(w.row_pages[slot]),
                        min_donate_idx=len(hit.shared) if hit else 0)
                if w.row_hits[slot] is not None:
                    # drop this row's read refs on the shared prefix pages
                    w.cache.release(w.row_hits[slot])
                    w.row_hits[slot] = None
                leftover = [p for p in w.row_pages[slot] if p not in donated]
                if leftover:
                    # free before the refill below so the incoming request
                    # can reuse this row's pages immediately
                    w.pool.free(leftover)
                w.row_pages[slot] = []
                w.row_tables[slot] = None
            if not (self.refill and self.queue
                    and self._fits(self.queue[0])):
                return
            self._install(slot, self.queue.pop(0))
            self.stats["refills"] += 1
            if w.filled[slot] < w.targets[slot]:
                return
            # adopted request was satisfied by its prefill alone
            # (max_new <= 1): keep draining the queue into this slot

    def _finish_wave(self) -> None:
        w = self.wave
        self._flush_anchors()
        dt = self.clock.now() - w.t0
        self.stats["cycles"] += w.cycles * len(w.requests)
        self.stats["wall_s"] += dt
        self.stats["waves"] += 1
        self.stats["alpha"] = (self._alpha_num / self._alpha_den
                               if self._alpha_den else 0.0)
        if self._warm_durs:
            self.stats["warm_cycle_s"] = float(np.median(self._warm_durs))
            self.stats["warm_cycles"] = len(self._warm_durs)
        if w.pool is not None:
            self.stats["pool_peak_pages"] = max(
                self.stats["pool_peak_pages"], w.pool.peak_in_use)
            self.stats["pool_utilization"] = (
                self._util_sum / self._util_samples
                if self._util_samples else 0.0)
        if w.cache is not None:
            # delta since wave start: an engine-lifetime cache accumulates
            # evictions across waves and must not be re-counted per wave
            self.stats["prefix_evictions"] += w.cache.evictions - w.evictions0
            self.stats["prefix_cached_pages"] = w.cache.cached_pages
        if w.pool is not None and self.pool_scope == "engine":
            # borrowed-pool contract: harvest the device pool buffers so
            # the next wave's state re-adopts them (cached prefix pages
            # keep their KV across the turnover)
            self._pools = capture_pools(w.state)
        self.wave = None

    # ----------------------------------------------------- drain loop -----
    def run(self) -> Dict:
        """Synchronous drain loop (dispatch + complete back-to-back).

        ``wall_s`` accumulates per-wave deltas of the injected
        :class:`~repro.serving.metrics.Clock` — monotonic wall time by
        default, deterministic simulated time under a ``VirtualClock`` —
        the same timing source the async front-end uses, so sync and
        overlapped numbers are directly comparable."""
        while self.queue or self.wave is not None:
            if self.wave is None and not self.start_wave():
                break
            # start_wave can finish a wave outright (all-max_new<=1 burst)
            while self.wave is not None and self.step():
                pass
        s = dict(self.stats)
        s["tokens_per_s"] = (s["tokens"] / s["wall_s"]
                             if s["wall_s"] else 0.0)
        return s
