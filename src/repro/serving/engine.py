"""Batched D2SD serving engine.

Wave-based continuous batching: requests queue up, waves of ``batch_size``
uniform-prompt-length requests run the speculative decode loop together
(per-example ragged lengths inside a wave are native — the engine state
carries per-request cache lengths). Tracks per-request and aggregate
acceptance/latency statistics.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.core import pipeline as pl


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # [P]
    max_new: int
    out: Optional[np.ndarray] = None
    n_cycles: int = 0
    latency_s: float = 0.0


class ServingEngine:
    def __init__(self, bundle: pl.SpecBundle, batch_size: int = 8,
                 seed: int = 0):
        self.bundle = bundle
        self.batch_size = batch_size
        self.queue: List[Request] = []
        self.done: List[Request] = []
        self.key = jax.random.PRNGKey(seed)
        self.stats = {"tokens": 0, "cycles": 0, "accepted": 0,
                      "wall_s": 0.0, "waves": 0}

    def submit(self, prompt: np.ndarray, max_new: int) -> int:
        uid = len(self.queue) + len(self.done)
        self.queue.append(Request(uid, np.asarray(prompt, np.int32),
                                  max_new))
        return uid

    def _next_wave(self) -> List[Request]:
        if not self.queue:
            return []
        # group by prompt length (uniform-length waves)
        self.queue.sort(key=lambda r: len(r.prompt))
        plen = len(self.queue[0].prompt)
        wave = [r for r in self.queue if len(r.prompt) == plen]
        wave = wave[: self.batch_size]
        for r in wave:
            self.queue.remove(r)
        return wave

    def run(self) -> Dict:
        while self.queue:
            wave = self._next_wave()
            prompts = np.stack([r.prompt for r in wave])
            max_new = max(r.max_new for r in wave)
            self.key, sub = jax.random.split(self.key)
            t0 = time.time()
            out = pl.generate(self.bundle, prompts, max_new=max_new,
                              key=sub, collect_stats=False)
            dt = time.time() - t0
            for i, r in enumerate(wave):
                r.out = out["tokens"][i, : r.max_new]
                r.n_cycles = out["n_cycles"]
                r.latency_s = dt
                self.done.append(r)
            n_tok = sum(min(r.max_new, out["tokens"].shape[1])
                        for r in wave)
            self.stats["tokens"] += n_tok
            self.stats["cycles"] += out["n_cycles"] * len(wave)
            self.stats["wall_s"] += dt
            self.stats["waves"] += 1
            self.stats["alpha"] = out["alpha"]
        s = dict(self.stats)
        s["tokens_per_s"] = (s["tokens"] / s["wall_s"]
                             if s["wall_s"] else 0.0)
        return s
