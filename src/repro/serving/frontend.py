"""Async serving front-end: a clocked event loop with overlapped waves.

The :class:`~repro.serving.engine.ServingEngine` drain loop is a *batch
replayer*: everything is submitted up front and the host blocks on every
decode cycle. This module drives the same engine as an open-loop server —
requests arrive on a traffic trace's schedule
(:mod:`repro.serving.traffic`), carry arrival timestamps, and are admitted
by a scheduling loop that overlaps host admission work with device decode.

Borrowed-pool overlap contract
------------------------------
The overlap is built on JAX async dispatch plus the engine's donated
install path, and is sound because the operations the host interleaves
touch disjoint device state:

* :meth:`ServingEngine.dispatch_cycle` enqueues decode cycle N and returns
  immediately; the active-row mask was snapshotted BEFORE dispatch, so the
  cycle mutates only rows that were serving requests at that instant —
  idle rows commit nothing.
* While the device decodes, the front-end pumps due arrivals and calls
  :meth:`ServingEngine.admit_idle`: queued prompts are matched, their
  pages come from the wave's *spare* pool capacity (the host-side
  allocator hands out only free pages — never pages a live row or the
  radix tree holds), same-length-bucket groups collapse into ONE batched
  :func:`~repro.core.state.install_rows` dispatch, and the donated install
  is enqueued BEHIND the in-flight cycle on the device stream. Cycle N
  writes rows it owns; install N+1 writes rows + pages it owns; the device
  serializes them without any host sync.
* The install's anchor token (the request's first generated token) is NOT
  read back inline — the engine defers it (pending-anchor) to the next
  retire boundary, where :meth:`ServingEngine.complete_cycle` performs the
  wave's single blocking read (``jax.block_until_ready`` semantics via
  ``np.asarray``) and retires finished requests.

Against the synchronous baseline (identical pumping, no overlap window)
the win is structural, not just latency-hiding: the sync engine refills a
slot only at the moment a retire happens, so a slot that goes idle while
the queue is momentarily empty stays idle until the wave drains; the
overlapped loop re-examines idle slots every cycle, so a burst that lands
mid-wave starts one *cycle* later instead of one *wave* later — fewer
total engine cycles for the same token-identical output (asserted by
``benchmarks/serving_bench.py --suite sla``).

Both drivers share the engine's injected clock and
:class:`~repro.serving.metrics.MetricsRecorder`, so their TTFT/TPOT/e2e
distributions and queue-depth timelines are directly comparable; on a
:class:`~repro.serving.metrics.VirtualClock` a replay is fully
deterministic.

Mesh residency: nothing here is mesh-aware by design. The engine
captures its ``use_sharding`` context at construction and re-enters it
(threadlocal) around every device-facing call — dispatch, installs,
``start_wave`` — so this event loop can drive a ``kv_seq``-sharded
engine from any thread without threading mesh state through the
scheduler. Batched admissions (``install_rows``) and prefix-hit warm
starts work identically on a mesh; the cascade verify inside the cycle
runs under ``shard_map`` with its per-shard stats psum-merged
(token-identical, see ``serving/engine.py``).
"""
from __future__ import annotations

from typing import Dict, Sequence

from repro.serving.engine import ServingEngine
from repro.serving.metrics import MetricsRecorder
from repro.serving.traffic import Arrival


class ReplayDriver:
    """Replay an arrival trace through a :class:`ServingEngine`.

    overlap=True (the async front-end): dispatch cycle N, then — while it
    decodes — pump due arrivals, admit them into idle slots, and only then
    block on the cycle's results. overlap=False (the synchronous
    baseline): identical pumping and timing, but no mid-flight admission —
    slots refill only at retire moments, exactly the drain-loop behavior.

    The driver owns the *event loop*; all engine state, admission policy,
    and metrics emission stay in the engine. When the engine sits idle
    with nothing due, the loop jumps the injected clock to the next
    arrival (``clock.wait_until`` — a real sleep on a monotonic clock, an
    instant jump in virtual time).
    """

    def __init__(self, engine: ServingEngine, trace: Sequence[Arrival],
                 overlap: bool = True):
        assert engine.recorder is not None, \
            "replay drivers need an engine with a MetricsRecorder"
        self.engine = engine
        self.trace = sorted(trace, key=lambda a: a.t)
        self.overlap = overlap
        self.engine_cycles = 0      # decode cycles dispatched by this loop
        self._next = 0

    # ------------------------------------------------------------- loop ----
    def _pump(self) -> int:
        """Submit every trace arrival whose time has come."""
        eng, n = self.engine, 0
        while (self._next < len(self.trace)
               and self.trace[self._next].t <= eng.clock.now()):
            a = self.trace[self._next]
            eng.submit(a.prompt, a.max_new, t_arrival=a.t)
            self._next += 1
            n += 1
        return n

    @property
    def _drained(self) -> bool:
        eng = self.engine
        return (self._next >= len(self.trace) and not eng.queue
                and eng.wave is None)

    def run(self) -> Dict:
        """Drive the trace to completion; returns engine stats + ``sla``
        summary + this loop's dispatched ``engine_cycles``."""
        eng = self.engine
        rec: MetricsRecorder = eng.recorder
        while not self._drained:
            self._pump()
            if eng.wave is None:
                if eng.queue:
                    # start_wave batch-installs the initial set (and can
                    # even finish the wave outright for max_new<=1 bursts).
                    # Full-width waves: open-loop arrivals trickle in, so
                    # rows beyond the visible batch start idle and are
                    # claimed by refills (sync: at retires; overlapped:
                    # any cycle via admit_idle)
                    eng.start_wave(width=eng.batch_size)
                    continue
                # idle: jump/sleep to the next arrival
                eng.clock.wait_until(self.trace[self._next].t)
                continue
            handle = eng.dispatch_cycle()
            self.engine_cycles += 1
            # ---- overlap window: the device is decoding cycle N ----
            self._pump()            # arrivals due during this cycle
            # sampled between pump and admission, so depth(t) is exactly
            # #arrivals<=t - #admits<t (admissions below stamp t_admit at
            # or after this instant; tests reconstruct the timeline from
            # the recorder's events and assert equality)
            rec.sample_queue_depth(len(eng.queue))
            if self.overlap:
                eng.admit_idle()    # fill idle slots mid-flight
            # ---- retire boundary: the wave's only blocking read ----
            eng.complete_cycle(handle)
        stats = dict(eng.stats)
        stats["engine_cycles"] = self.engine_cycles
        stats["sla"] = rec.summary()
        return stats


class OverlappedFrontend(ReplayDriver):
    """The async front-end: overlapped scheduling (``overlap=True``)."""

    def __init__(self, engine: ServingEngine, trace: Sequence[Arrival]):
        super().__init__(engine, trace, overlap=True)


class SyncReplay(ReplayDriver):
    """Synchronous baseline with identical pumping/timing
    (``overlap=False``): refill only at retire moments."""

    def __init__(self, engine: ServingEngine, trace: Sequence[Arrival]):
        super().__init__(engine, trace, overlap=False)


def replay(engine: ServingEngine, trace: Sequence[Arrival],
           overlap: bool = True) -> Dict:
    """One-shot convenience: build a driver, run the trace, return stats
    (engine aggregates + ``sla`` section + ``engine_cycles``)."""
    return ReplayDriver(engine, trace, overlap=overlap).run()
