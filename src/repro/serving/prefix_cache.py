"""Radix prefix cache: cross-request page sharing over a PagePool.

D2SD's candidate organization is built on shared prefixes *inside* a draft
block; this module applies the same economics *across the request
population* (vLLM prefix caching / SGLang RadixAttention style). A
host-side radix tree indexes the committed token strings of retired
requests; each tree node owns a run of physical pages in a
:class:`~repro.models.kvcache.PagePool` holding the target KV **and both
drafter feature caches** for its token span (every paged cache of a wave
shares one page-id space, so one node covers all three). The tree lives
as long as its pool: with the serving engine's default engine-lifetime
pool the tree OUTLIVES wave turnover — wave N+1's prompts hit prefixes
committed in wave N (resident serving; the engine carries the device
pool buffers across via ``core.state.capture_pools``/``adopt_pools``) —
while a legacy per-wave pool scopes it to one wave. Admitting a request
whose prompt extends a cached string becomes a page-table splice:

* **match** — longest cached prefix of the prompt (capped at ``P - 1``:
  at least one suffix token must remain to produce the anchor logits);
* **share** — the full pages covering the match are refcount-bumped and
  written into the new row's page table; the suffix is the only part that
  is prefilled (``install_row(prefix_hit=...)``);
* **COW** — when the match ends inside a page, that partially filled tail
  page is copied to a freshly allocated page before the row's first write
  (:func:`repro.core.state.cow_copy_page`), upholding the pool invariant
  that *a page with refcount > 1 is never written*;
* **insert** — at retire, the request's committed string (prompt +
  generated tokens actually committed to cache) is inserted back: the
  private pages covering the new suffix are donated to the tree (their
  refcount passes over), duplicated spans and allocation headroom are
  freed;
* **evict** — under pool pressure, least-recently-used *unpinned* leaf
  nodes are evicted and their pages returned. A node is pinned exactly
  while an in-flight row still reads one of its pages (pool refcount > 1),
  and eviction refuses pinned nodes. Pinning is refcount-based, so it is
  wave-agnostic: a row in ANY live wave of an engine-lifetime pool holds
  its read refs until retire, and eviction pressure is engine-global
  (driven by the shared pool's occupancy, not per-wave sizing).

Everything here is host-side bookkeeping over integer page ids — device
state is only touched by the engine (COW copy + installs).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.models import kvcache as kvc


class RadixNode:
    """One edge of the radix tree.

    edge:  the token run this node contributes (np.int32 [E], E >= 1 for
           every node except the root).
    start: absolute token offset of ``edge[0]`` in the cached string.
    pages: ``[(page_index, physical_page)]`` owned by this node — the
           pages whose first position falls inside [start, end), plus (for
           a node created from a mid-page branch) one *override* entry for
           the boundary page index, shadowing the ancestor's partially
           shared page with this branch's COW copy.
    """

    __slots__ = ("edge", "start", "children", "pages", "parent", "last_use")

    def __init__(self, edge: np.ndarray, start: int,
                 parent: Optional["RadixNode"]):
        self.edge = edge
        self.start = int(start)
        self.children: Dict[int, "RadixNode"] = {}
        self.pages: List[Tuple[int, int]] = []
        self.parent = parent
        self.last_use = 0

    @property
    def end(self) -> int:
        return self.start + len(self.edge)


@dataclasses.dataclass
class PrefixHit:
    """A successful prompt match.

    length:  matched token count (the row's warm-start ``prefix_hit``).
    shared:  physical pages fully covered by the match — spliced into the
             row's table read-only (refcount bumped by :meth:`acquire`).
    partial: physical page holding position ``length`` when the match ends
             mid-page — the COW source (held alive by a temporary ref
             between :meth:`acquire` and :meth:`release_partial`).
    """
    length: int
    shared: List[int]
    partial: Optional[int]


class PrefixCache:
    """Host-side radix tree over committed prefixes of one pool — per-wave
    or engine-lifetime, whichever scope the owning engine runs."""

    def __init__(self, pool: kvc.PagePool):
        self.pool = pool
        self.page = pool.page_size
        self.root = RadixNode(np.zeros((0,), np.int32), 0, None)
        self._tick = 0
        self.evictions = 0

    # ------------------------------------------------------------- walk ----
    def _walk(self, tokens: np.ndarray):
        """Longest-prefix walk. Returns (node, off, matched, path): the
        deepest node reached, the offset inside its edge where matching
        stopped, the total matched token count, and the root->node path."""
        node = self.root
        path = [node]
        m, n = 0, len(tokens)
        while m < n:
            child = node.children.get(int(tokens[m]))
            if child is None:
                return node, len(node.edge), m, path
            e = child.edge
            k = min(len(e), n - m)
            neq = np.nonzero(e[:k] != tokens[m: m + k])[0]
            j = int(neq[0]) if len(neq) else k
            m += j
            path.append(child)
            node = child
            if j < len(e):
                return node, j, m, path
        return node, len(node.edge), m, path

    def _page_map(self, path: List[RadixNode], n_idx: int) -> Dict[int, int]:
        """page_index -> physical page for indices < n_idx along ``path``
        (deeper nodes override ancestors at boundary indices)."""
        mp: Dict[int, int] = {}
        for node in path:
            for i, p in node.pages:
                if i < n_idx:
                    mp[i] = p
        return mp

    # ------------------------------------------------------------ lookup ---
    def lookup(self, prompt: np.ndarray) -> Optional[PrefixHit]:
        """Longest cached prefix of ``prompt`` (read-only, no refcounts).

        The match is capped at ``len(prompt) - 1`` so the install always
        prefills at least one token (the anchor comes from real logits).
        """
        prompt = np.asarray(prompt, np.int32)
        node, off, m, path = self._walk(prompt)
        m = min(m, len(prompt) - 1)
        if m <= 0:
            return None
        self._tick += 1
        for nd in path:
            nd.last_use = self._tick
        n_full = m // self.page
        mp = self._page_map(path, kvc.pages_for(m, self.page))
        shared = [mp[i] for i in range(n_full)]
        partial = mp[n_full] if m % self.page else None
        return PrefixHit(length=m, shared=shared, partial=partial)

    def acquire(self, hit: PrefixHit) -> None:
        """Pin a hit: one read ref per shared page for the row's lifetime,
        plus a temporary ref on the COW source page (released right after
        the copy by :meth:`release_partial`)."""
        self.pool.incref(hit.shared)
        if hit.partial is not None:
            self.pool.incref([hit.partial])

    def release_partial(self, hit: PrefixHit) -> None:
        if hit.partial is not None:
            self.pool.free([hit.partial])

    def release(self, hit: PrefixHit) -> None:
        """Drop the row's read refs at retire (or on an aborted install)."""
        self.pool.free(hit.shared)

    # ------------------------------------------------------------ insert ---
    def insert(self, tokens: np.ndarray, row_table: np.ndarray,
               private=None, min_donate_idx: int = 0) -> Set[int]:
        """Insert a retired row's committed token string.

        ``row_table``: logical page index -> physical page for the row.
        Returns the physical pages DONATED to the tree — their refcount
        transfers (the caller must NOT free them). Pages covering spans
        the tree already holds, and allocation headroom beyond the
        committed length, stay with the caller. ``private``: the row's
        exclusively owned pages — donations must come from it (shared
        pages already belong to the tree; donating one would fork
        ownership).

        ``min_donate_idx``: the row's first PRIVATE page index (its
        install-time shared-page count). The walk below can stop SHORT of
        the row's original hit length — eviction may have removed a
        page-less split node from the matched path while the row was in
        flight (such nodes own no pages, so page-refcount pinning cannot
        protect them) — and the re-derived boundary ``m // page`` would
        then reach into the row's shared pages. Donation is clamped to
        start at ``min_donate_idx``; coverage stays complete because
        every index below it resolves through the surviving (pinned)
        owners of the row's shared pages, which are always at or above
        the point where the walk stopped.
        """
        tokens = np.asarray(tokens, np.int32)
        c = len(tokens)
        if c <= 0:
            return set()
        node, off, m, path = self._walk(tokens)
        self._tick += 1
        for nd in path:
            nd.last_use = self._tick
        if m >= c:
            return set()                    # string fully cached already
        if off < len(node.edge):
            node = self._split(node, off)
        # boundary override at m // page; clamped off the row's shared span
        first = max(m // self.page, int(min_donate_idx))
        pages = [(i, int(row_table[i]))
                 for i in range(first, kvc.pages_for(c, self.page))]
        if private is not None:
            assert all(p in private for _, p in pages), \
                "radix insert would donate a page the row does not own"
        child = RadixNode(tokens[m:c].copy(), m, node)
        child.pages = pages
        child.last_use = self._tick
        node.children[int(tokens[m])] = child
        return {p for _, p in pages}

    def _split(self, node: RadixNode, off: int) -> RadixNode:
        """Split ``node``'s edge at ``off`` (0 < off < len(edge)); the
        original object becomes the upper half (parent links stay valid)
        and a new child carries the lower half. Pages partition by page
        start position; a page straddling the split stays with the upper
        half (the lower half reads it through its ancestor)."""
        assert 0 < off < len(node.edge)
        split_abs = node.start + off
        lower = RadixNode(node.edge[off:].copy(), split_abs, node)
        lower.children = node.children
        for ch in lower.children.values():
            ch.parent = lower
        lower.pages = [(i, p) for i, p in node.pages
                       if i * self.page >= split_abs]
        lower.last_use = node.last_use
        node.pages = [(i, p) for i, p in node.pages
                      if i * self.page < split_abs]
        node.edge = node.edge[:off].copy()
        node.children = {int(lower.edge[0]): lower}
        return node

    # ---------------------------------------------------------- eviction ---
    def _nodes(self):
        stack = [self.root]
        while stack:
            n = stack.pop()
            yield n
            stack.extend(n.children.values())

    def _pinned(self, node: RadixNode) -> bool:
        """A node is pinned while any in-flight row still reads one of its
        pages (pool refcount > 1 — the tree itself holds exactly one)."""
        return any(self.pool.refcount(p) != 1 for _, p in node.pages)

    @property
    def n_nodes(self) -> int:
        return sum(1 for _ in self._nodes()) - 1        # excl. root

    @property
    def cached_pages(self) -> int:
        return sum(len(n.pages) for n in self._nodes())

    def evictable_pages(self) -> int:
        """Pages reclaimable right now: nodes whose entire subtree is
        unpinned (leaves must go before ancestors, so a pinned descendant
        blocks the whole chain above it)."""
        def rec(n: RadixNode) -> Tuple[int, bool]:
            cnt, clean = 0, not self._pinned(n)
            for ch in n.children.values():
                c_cnt, c_clean = rec(ch)
                cnt += c_cnt
                clean &= c_clean
            if clean and n is not self.root:
                cnt += len(n.pages)
            return cnt, clean

        return rec(self.root)[0]

    def evict_for(self, n_pages: int) -> bool:
        """LRU-evict unpinned leaves until ``pool.free_pages >= n_pages``.

        Pinned nodes are REFUSED (their pages have in-flight readers);
        returns False if pressure cannot be satisfied — the caller must
        then deny admission, never force-free.
        """
        while self.pool.free_pages < n_pages:
            victim = None
            for node in self._nodes():
                if node is self.root or node.children:
                    continue
                if self._pinned(node):
                    continue
                if victim is None or node.last_use < victim.last_use:
                    victim = node
            if victim is None:
                return False
            if victim.pages:
                self.pool.free([p for _, p in victim.pages])
            del victim.parent.children[int(victim.edge[0])]
            self.evictions += 1
        return True
