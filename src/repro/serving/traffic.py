"""Open-loop traffic generation: seeded arrival processes over the task mix.

A closed-loop replayer (submit a batch, wait, submit the next) can never
observe queueing — the client politely waits for the server. Open-loop
traffic fires requests on a schedule that does NOT depend on server
progress, which is what exposes TTFT/TPOT tails and queue-depth growth
under load. Two arrival processes:

* **poisson** — i.i.d. exponential inter-arrival times at ``rate`` req/s.
  The memoryless baseline most serving papers quote.
* **bursty** — a 2-state Markov-modulated Poisson process (MMPP): the
  source dwells in a *calm* state (rate ``rate * calm_scale``) and a
  *burst* state (rate ``rate * burst_scale``), with exponential dwell
  times. Same mean arrival intensity knob as poisson, but arrivals clump —
  the adversarial case for wave-synchronous scheduling, because a clump
  lands while a wave is mid-flight and a retire-moment-only admitter
  leaves slots idle until the next wave.

Prompts come from the synthetic task mix (:mod:`repro.data.synthetic` —
math/code/chat round-robin by default), re-ranged into the serving
vocabulary when the bench runs a tiny-vocab bundle. Everything is
deterministic in ``seed``: the same trace replays identically through the
synchronous engine and the overlapped front-end, which is what makes
per-request token-identity assertions possible.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.data import synthetic


@dataclasses.dataclass
class Arrival:
    """One open-loop request: submit ``prompt`` at absolute time ``t``."""
    t: float
    prompt: np.ndarray
    max_new: int

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)


def _prompt(i: int, seed: int, prompt_len: int, vocab: Optional[int],
            tasks: Sequence[str]) -> np.ndarray:
    """Deterministic prompt #i: task round-robins through ``tasks``, the
    generator rng is keyed on (seed, i). ``vocab`` re-ranges generator
    output into [3, vocab) for tiny-vocab serving bundles (BOS/EOS/PAD
    stay reserved); None keeps the native synthetic vocabulary."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, i]))
    gen = synthetic.GENERATORS[tasks[i % len(tasks)]]
    toks = gen(rng, prompt_len)[:prompt_len].astype(np.int32)
    if vocab is not None:
        assert vocab > 3, f"vocab {vocab} leaves no room beyond specials"
        toks = np.where(toks < 3, toks, (toks - 3) % (vocab - 3) + 3)
    return toks.astype(np.int32)


def _materialize(times: List[float], seed: int, prompt_lens: Sequence[int],
                 max_new, vocab: Optional[int],
                 tasks: Sequence[str]) -> List[Arrival]:
    rng = np.random.default_rng(np.random.SeedSequence([seed, 1 << 20]))
    news = ([int(max_new)] if isinstance(max_new, (int, np.integer))
            else [int(x) for x in max_new])
    out = []
    for i, t in enumerate(times):
        pl = int(prompt_lens[int(rng.integers(len(prompt_lens)))])
        mn = news[int(rng.integers(len(news)))]
        out.append(Arrival(t=float(t),
                           prompt=_prompt(i, seed, pl, vocab, tasks),
                           max_new=mn))
    return out


def poisson_trace(rate: float, duration: float, seed: int = 0,
                  prompt_lens: Sequence[int] = (12, 12, 20, 28),
                  max_new=16, vocab: Optional[int] = None,
                  tasks: Sequence[str] = synthetic.TASKS) -> List[Arrival]:
    """Poisson arrivals at ``rate`` req/s for ``duration`` seconds.
    ``prompt_lens`` / ``max_new`` may be sequences — each request samples
    uniformly from them (mixed decode budgets)."""
    assert rate > 0 and duration > 0
    rng = np.random.default_rng(np.random.SeedSequence([seed, 7]))
    times, t = [], 0.0
    while True:
        t += float(rng.exponential(1.0 / rate))
        if t >= duration:
            break
        times.append(t)
    return _materialize(times, seed, prompt_lens, max_new, vocab, tasks)


def bursty_trace(rate: float, duration: float, seed: int = 0,
                 calm_scale: float = 0.2, burst_scale: float = 4.0,
                 mean_dwell: float = 2.0,
                 prompt_lens: Sequence[int] = (12, 12, 20, 28),
                 max_new=16, vocab: Optional[int] = None,
                 tasks: Sequence[str] = synthetic.TASKS) -> List[Arrival]:
    """2-state MMPP: alternate calm (``rate * calm_scale``) and burst
    (``rate * burst_scale``) Poisson regimes with exponential dwell times
    of mean ``mean_dwell`` seconds, starting calm."""
    assert rate > 0 and duration > 0
    rng = np.random.default_rng(np.random.SeedSequence([seed, 11]))
    rates = (rate * calm_scale, rate * burst_scale)
    times: List[float] = []
    t, state = 0.0, 0
    while t < duration:
        t_switch = t + float(rng.exponential(mean_dwell))
        r = rates[state]
        while True:
            t += float(rng.exponential(1.0 / r))
            if t >= t_switch or t >= duration:
                break
            times.append(t)
        t = min(t_switch, t)
        state ^= 1
    times = [x for x in times if x < duration]
    return _materialize(times, seed, prompt_lens, max_new, vocab, tasks)


TRACES = {"poisson": poisson_trace, "bursty": bursty_trace}


def make_trace(kind: str, rate: float, duration: float, seed: int = 0,
               **kw) -> List[Arrival]:
    """Build a named arrival trace (``poisson`` | ``bursty``)."""
    if kind not in TRACES:
        raise ValueError(f"unknown traffic kind {kind!r}; "
                         f"choose from {sorted(TRACES)}")
    return TRACES[kind](rate, duration, seed=seed, **kw)
