"""SLA metrics layer: clocks + per-request latency accounting.

Serving performance under load is a *latency distribution*, not a
throughput scalar — queueing collapse shows up in TTFT/TPOT tails long
before tokens/s moves. This module is the single timing source for both
serving drivers (the synchronous :meth:`ServingEngine.run` drain loop and
the overlapped :class:`~repro.serving.frontend.OverlappedFrontend`), so
their numbers are directly comparable:

* **Clocks** — every engine timestamp goes through an injected
  :class:`Clock`. :class:`MonotonicClock` is the production default
  (monotonic wall time; ``tick`` is a no-op because real time passes by
  itself). :class:`VirtualClock` is a deterministic simulated clock: time
  only moves when someone calls :meth:`~VirtualClock.advance` /
  :meth:`~VirtualClock.wait_until`, or when the engine charges work via
  :meth:`~VirtualClock.tick` (one decode cycle = ``cycle_s``, one request
  install = ``install_s``). Benchmarks and tests replay traffic on a
  VirtualClock so latency numbers are exactly reproducible and
  independent of host speed; the same replay on a MonotonicClock measures
  real wall time with identical code paths.
* **Per-request lifecycle** — :class:`MetricsRecorder` timestamps the four
  request events (arrival, admission into a batch slot, first generated
  token, completion) and derives TTFT (first token − arrival), TPOT
  (steady-state seconds per generated token after the first), end-to-end
  latency, and queue wait. The serving engine emits the events itself
  (``submit`` / install / retire), so any driver on top of it gets
  per-request SLA metrics for free.
* **Queue-depth timeline** — drivers call :meth:`sample_queue_depth`
  once per scheduling iteration; the (t, depth) series is what exposes
  open-loop queueing collapse (depth growing without bound when the
  arrival rate exceeds service capacity).

Aggregation is nearest-rank percentiles (:func:`percentile`): exact order
statistics of the observed sample, so hand-built schedules in tests can
assert aggregate values to equality instead of approximately.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple


# ------------------------------------------------------------------ clocks --
class Clock:
    """Timing interface the serving stack is written against."""

    def now(self) -> float:
        raise NotImplementedError

    def wait_until(self, t: float) -> None:
        """Block (or jump, for virtual time) until ``now() >= t``."""
        raise NotImplementedError

    def tick(self, kind: str, n: int = 1) -> None:
        """Charge ``n`` units of simulated work (no-op on real clocks)."""


class MonotonicClock(Clock):
    """Real monotonic wall time, zeroed at construction.

    ``tick`` is a no-op: real work takes real time. This is the engine's
    default clock, replacing the old ad-hoc ``time.time()`` deltas (which
    were not monotonic-safe and unshareable with the async front-end).
    """

    def __init__(self):
        self._t0 = time.monotonic()

    def now(self) -> float:
        return time.monotonic() - self._t0

    def wait_until(self, t: float) -> None:
        dt = t - self.now()
        if dt > 0:
            time.sleep(dt)


class VirtualClock(Clock):
    """Deterministic simulated clock for replays and tests.

    Time advances only through :meth:`advance` / :meth:`wait_until` (the
    open-loop driver jumping to the next arrival) and :meth:`tick` (the
    engine charging work): one decode cycle costs ``cycle_s`` and one
    request install costs ``install_s``. Unknown tick kinds default to
    ``0.0`` cost, so new instrumentation never breaks old replays.
    """

    def __init__(self, cycle_s: float = 1.0, install_s: float = 0.25):
        self._t = 0.0
        self.costs = {"cycle": float(cycle_s), "install": float(install_s)}

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> None:
        assert dt >= 0, f"time cannot run backwards ({dt})"
        self._t += dt

    def wait_until(self, t: float) -> None:
        if t > self._t:
            self._t = t

    def tick(self, kind: str, n: int = 1) -> None:
        self._t += self.costs.get(kind, 0.0) * n


# --------------------------------------------------------------- lifecycle --
@dataclasses.dataclass
class RequestTiming:
    """The four lifecycle timestamps of one request + derived SLA terms.

    ``t_first`` is the time the request's FIRST generated token exists —
    the prefill's anchor token, stamped when the install is dispatched.
    """
    uid: int
    t_arrival: Optional[float] = None
    t_admit: Optional[float] = None
    t_first: Optional[float] = None
    t_done: Optional[float] = None
    n_tokens: int = 0

    @property
    def ttft(self) -> float:
        """Time to first token: arrival -> first generated token."""
        return self.t_first - self.t_arrival

    @property
    def tpot(self) -> float:
        """Time per output token AFTER the first (steady-state decode
        rate); 0.0 for single-token requests."""
        if self.n_tokens <= 1:
            return 0.0
        return (self.t_done - self.t_first) / (self.n_tokens - 1)

    @property
    def e2e(self) -> float:
        """End-to-end latency: arrival -> last token."""
        return self.t_done - self.t_arrival

    @property
    def queue_wait(self) -> float:
        """Arrival -> admission into a batch slot (pure queueing delay)."""
        return self.t_admit - self.t_arrival

    @property
    def complete(self) -> bool:
        return self.t_done is not None


def percentile(xs: Sequence[float], q: float) -> float:
    """Nearest-rank percentile (exact order statistic, no interpolation):
    the smallest observed value >= ``q`` percent of the sample. Exact on
    hand-built schedules, which is what the scheduler tests assert."""
    assert xs, "percentile of an empty sample"
    s = sorted(xs)
    rank = max(int(math.ceil(q / 100.0 * len(s))), 1)
    return float(s[min(rank, len(s)) - 1])


def summarize(xs: Sequence[float]) -> Dict[str, float]:
    """p50/p90/p99/mean/max of a sample (empty -> all zeros)."""
    if not xs:
        return {"p50": 0.0, "p90": 0.0, "p99": 0.0, "mean": 0.0, "max": 0.0}
    return {"p50": percentile(xs, 50), "p90": percentile(xs, 90),
            "p99": percentile(xs, 99),
            "mean": float(sum(xs) / len(xs)), "max": float(max(xs))}


class MetricsRecorder:
    """Collects per-request lifecycle events + a queue-depth timeline.

    Event methods stamp ``clock.now()`` unless an explicit time is given
    (open-loop drivers pass the trace's arrival time to ``on_arrival`` so
    TTFT counts from when the CLIENT sent the request, not from when the
    server's scheduling loop first looked at its queue).
    """

    def __init__(self, clock: Clock):
        self.clock = clock
        self.requests: Dict[int, RequestTiming] = {}
        self.queue_depth: List[Tuple[float, int]] = []

    def _req(self, uid: int) -> RequestTiming:
        if uid not in self.requests:
            self.requests[uid] = RequestTiming(uid)
        return self.requests[uid]

    def on_arrival(self, uid: int, t: Optional[float] = None) -> None:
        self._req(uid).t_arrival = self.clock.now() if t is None else t

    def on_admit(self, uid: int, t: Optional[float] = None) -> None:
        self._req(uid).t_admit = self.clock.now() if t is None else t

    def on_first_token(self, uid: int, t: Optional[float] = None) -> None:
        self._req(uid).t_first = self.clock.now() if t is None else t

    def on_done(self, uid: int, n_tokens: int,
                t: Optional[float] = None) -> None:
        r = self._req(uid)
        r.t_done = self.clock.now() if t is None else t
        r.n_tokens = int(n_tokens)

    def sample_queue_depth(self, depth: int) -> None:
        self.queue_depth.append((self.clock.now(), int(depth)))

    # ------------------------------------------------------- aggregation --
    def completed(self) -> List[RequestTiming]:
        return sorted((r for r in self.requests.values() if r.complete),
                      key=lambda r: r.uid)

    def per_request(self) -> List[Dict[str, float]]:
        """One flat record per completed request (bench JSON payload)."""
        return [{"uid": r.uid, "ttft": r.ttft, "tpot": r.tpot,
                 "e2e": r.e2e, "queue_wait": r.queue_wait,
                 "n_tokens": r.n_tokens} for r in self.completed()]

    def summary(self) -> Dict:
        """Aggregate SLA section: p50/p90/p99/mean/max per metric, plus
        the queue-depth timeline's mean/max."""
        done = self.completed()
        depths = [d for _, d in self.queue_depth]
        return {
            "n_requests": len(done),
            "ttft": summarize([r.ttft for r in done]),
            "tpot": summarize([r.tpot for r in done]),
            "e2e": summarize([r.e2e for r in done]),
            "queue_wait": summarize([r.queue_wait for r in done]),
            "queue_depth": {
                "samples": len(depths),
                "mean": (float(sum(depths) / len(depths))
                         if depths else 0.0),
                "max": max(depths) if depths else 0,
            },
        }
