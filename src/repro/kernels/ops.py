"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; the
kernels TARGET TPU and execute their bodies in interpret mode for
correctness validation — assignment contract).

``flash_attention`` carries a custom_vjp wired to the Pallas backward
kernels, so the same op serves training.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import cascade_attention as casc
from repro.kernels import flash_attention as fa


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------- flash ----
@functools.partial(
    jax.custom_vjp,
    nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def _flash(q, k, v, causal, q_offset, window, kv_len, attn_softcap, scale,
           interpret):
    o, _ = fa.flash_attention_fwd(
        q, k, v, causal=causal, q_offset=q_offset, window=window,
        kv_len=kv_len, attn_softcap=attn_softcap, scale=scale,
        interpret=interpret)
    return o


def _flash_fwd(q, k, v, causal, q_offset, window, kv_len, attn_softcap,
               scale, interpret):
    o, lse = fa.flash_attention_fwd(
        q, k, v, causal=causal, q_offset=q_offset, window=window,
        kv_len=kv_len, attn_softcap=attn_softcap, scale=scale,
        interpret=interpret)
    return o, (q, k, v, o, lse)


def _flash_bwd(causal, q_offset, window, kv_len, attn_softcap, scale,
               interpret, res, do):
    q, k, v, o, lse = res
    dq, dk, dv = fa.flash_attention_bwd(
        q, k, v, o, lse, do, causal=causal, q_offset=q_offset, window=window,
        kv_len=kv_len, attn_softcap=attn_softcap, scale=scale,
        interpret=interpret)
    return dq.astype(q.dtype), dk, dv


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q, k, v, *, causal=True, q_offset=0, window=None,
                    kv_len=None, attn_softcap=None, scale=None,
                    interpret: Optional[bool] = None, layout="BTHD"):
    """Differentiable flash attention.

    layout "BTHD": q [B,T,Hq,D] (model-stack layout) or "BHTD" (kernel
    layout). Returns attention output in the same layout.
    """
    interpret = _default_interpret() if interpret is None else interpret
    if layout == "BTHD":
        q_, k_, v_ = (jnp.swapaxes(x, 1, 2) for x in (q, k, v))
    else:
        q_, k_, v_ = q, k, v
    o = _flash(q_, k_, v_, causal, q_offset, window, kv_len, attn_softcap,
               scale, interpret)
    return jnp.swapaxes(o, 1, 2) if layout == "BTHD" else o


# -------------------------------------------------------------- cascade ----
def cascade_attention(q, cache_k, cache_v, blk_k, blk_v, *, cache_len,
                      q_abs, tree_mask, window=None, attn_softcap=None,
                      scale=None, rolling=False, n_splits=8, bk=512,
                      interpret: Optional[bool] = None, layout="BTHD"):
    """The paper's cascade verify op (inference only)."""
    interpret = _default_interpret() if interpret is None else interpret
    if layout == "BTHD":
        q_, ck, cv, bk_, bv = (jnp.swapaxes(x, 1, 2)
                               for x in (q, cache_k, cache_v, blk_k, blk_v))
    else:
        q_, ck, cv, bk_, bv = q, cache_k, cache_v, blk_k, blk_v
    o = casc.cascade_attention(
        q_, ck, cv, bk_, bv, cache_len=cache_len, q_abs=q_abs,
        tree_mask=tree_mask, window=window, attn_softcap=attn_softcap,
        scale=scale, rolling=rolling, n_splits=n_splits, bk=bk,
        interpret=interpret)
    return jnp.swapaxes(o, 1, 2) if layout == "BTHD" else o


def cascade_attention_paged(q, pool_k, pool_v, page_table, blk_k, blk_v, *,
                            cache_len, q_abs, tree_mask, window=None,
                            attn_softcap=None, scale=None, n_splits=8,
                            interpret: Optional[bool] = None,
                            layout="BTHD", pos_stride=None, pos_offset=None):
    """Cascade verify over a PAGED cache (``cache_impl="paged"`` storage).

    ``pool_k`` / ``pool_v``: page pools in the engine's storage layout
    [P, page, Hkv, D] (``layout="BTHD"``, matching models/kvcache.py) or
    the kernel layout [P, Hkv, page, D] (``layout="BHTD"``).
    ``page_table`` [B, max_pages]: physical page of each logical page
    (out-of-range sentinel entries mark unallocated pages). The page table
    is scalar-prefetched so the Pallas kernel DMAs pages straight from the
    pool — no dense gather of the logical view, and the index_map clamps
    dead logical pages to the last live one so HBM traffic scales with
    ``cache_len``, not table capacity. ``pos_stride``/``pos_offset``
    relocate logical page ``i`` to absolute positions
    ``i*pos_stride + pos_offset + [0, page)`` for kv_seq-sharded pools
    (see ``cascade_attention.cascade_phase1_paged``).
    """
    interpret = _default_interpret() if interpret is None else interpret
    if layout == "BTHD":
        q_, bk_, bv = (jnp.swapaxes(x, 1, 2) for x in (q, blk_k, blk_v))
        pk, pv = (jnp.swapaxes(x, 1, 2) for x in (pool_k, pool_v))
    else:
        q_, bk_, bv, pk, pv = q, blk_k, blk_v, pool_k, pool_v
    o = casc.cascade_attention_paged(
        q_, pk, pv, page_table, bk_, bv, cache_len=cache_len, q_abs=q_abs,
        tree_mask=tree_mask, window=window, attn_softcap=attn_softcap,
        scale=scale, n_splits=n_splits, interpret=interpret,
        pos_stride=pos_stride, pos_offset=pos_offset)
    return jnp.swapaxes(o, 1, 2) if layout == "BTHD" else o
