"""Cascade tree-verification attention — the paper's verify op, TPU-native.

One D2SD verification joins K+1 shared-prefix candidates (a comb prefix
tree of T_tree tokens) against a LONG committed KV cache. FlashInfer's GPU
cascade kernel is re-thought for TPU (DESIGN §3):

  phase 1 (this Pallas kernel): the query block (all tree tokens, <= ~128)
    stays resident in VMEM while the kernel sweeps the KV cache HBM->VMEM in
    BlockSpec tiles, split-K over a grid axis so many cache slices progress
    in parallel; each split emits un-normalized flash partials (acc, m, l).
  phase 2 (jnp): partials merge by log-sum-exp with the tree-masked local
    part (tree tokens attending each other via the comb ancestor mask) —
    tiny (T_tree^2), not worth a kernel.

This is also the decode kernel: a chain of 1 token is a degenerate tree.

Masking supports per-example cache lengths (ragged batch), sliding windows
(gemma2/recurrentgemma local layers; rolling-buffer position recovery), and
per-query absolute positions (tree nodes sit at cache_len + depth).

Paged variant (:func:`cascade_phase1_paged`): the KV cache is a page pool
``[P, Hkv, page, D]`` plus per-row page tables ``[B, max_pages]`` (the
serving engine's ``cache_impl="paged"`` layout, models/kvcache.py). The
page table rides in as a scalar-prefetch operand
(``pltpu.PrefetchScalarGridSpec``) so the K/V BlockSpec ``index_map``
resolves each grid step's LOGICAL page to its PHYSICAL pool page before
the DMA is issued — the kernel streams exactly the row's pages out of HBM
with no gather materialization, keeping the same split-K grid and
ragged/sliding-window masking as the dense kernel (logical key positions
are unchanged; only the addressing is indirected).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _phase1_kernel(cache_len_ref, q_abs_ref,                  # SMEM
                   q_ref, k_ref, v_ref,                       # VMEM blocks
                   acc_ref, m_ref, l_ref,                     # outputs
                   racc, rm, rl,                              # scratch
                   *, bk, nk_inner, tq, window, softcap, scale, rolling,
                   cap):
    """``cap`` is the TRUE buffer capacity (``s_len``), NOT the padded
    grid extent: rolling position recovery ``kpos = last - rem(last -
    slot, cap)`` inverts the writer's ``slot = pos % cap``, so any other
    modulus recovers wrong absolute positions. Slots the split padding
    added (``slot >= cap``) hold no data and are masked dead explicitly —
    without that mask a padded slot at ``last + cap`` would alias the
    rolling recovery back onto a live position."""
    b = pl.program_id(0)
    s = pl.program_id(2)       # split index
    jj = pl.program_id(3)      # inner kv step within the split

    @pl.when(jj == 0)
    def _init():
        racc[...] = jnp.zeros_like(racc)
        rm[...] = jnp.full_like(rm, NEG_INF)
        rl[...] = jnp.zeros_like(rl)

    q = q_ref[0, 0].astype(jnp.float32) * scale              # [tq, D]
    k = k_ref[0, 0].astype(jnp.float32)                      # [bk, D]
    v = v_ref[0, 0].astype(jnp.float32)

    sc = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [tq, bk]
    if softcap is not None:
        sc = softcap * jnp.tanh(sc / softcap)

    clen = cache_len_ref[b]
    base = (s * nk_inner + jj) * bk
    slot = base + jax.lax.broadcasted_iota(jnp.int32, (bk,), 0)
    live = slot < cap          # padded slots carry no cache data
    qpos = q_abs_ref[pl.dslice(b * tq, tq)]                  # [tq]
    qp = qpos[:, None]
    if rolling:
        # slot j holds the largest t < clen with t % cap == j; rem (not
        # mod) is safe: last - slot < 0 only pre-wrap (clen <= cap, so
        # last < cap <= any candidate), where the recovered kpos > last
        # dies on kpos < clen exactly like the oracle's kpos < 0.
        last = clen - 1
        kpos = last - jax.lax.rem(last - slot, cap)
        ok = live & (kpos >= 0) & (kpos < clen) & (kpos <= qp)
    else:
        kpos = slot
        ok = live & (kpos < clen) & (kpos <= qp)
    if window is not None:
        ok &= kpos > (qp - window)
    sc = jnp.where(ok, sc, NEG_INF)

    m_prev = rm[...]
    m_new = jnp.maximum(m_prev, sc.max(axis=1))
    p = jnp.exp(sc - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    rl[...] = rl[...] * alpha + p.sum(axis=1)
    racc[...] = racc[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    rm[...] = m_new

    @pl.when(jj == nk_inner - 1)
    def _final():
        acc_ref[0, 0, 0] = racc[...]
        m_ref[0, 0, 0] = rm[...]
        l_ref[0, 0, 0] = rl[...]


def cascade_phase1(q, cache_k, cache_v, *, cache_len, q_abs, window=None,
                   attn_softcap=None, scale=None, rolling=False,
                   n_splits=8, bk=512, interpret=False):
    """q [B,Hq,Tq,D]; cache [B,Hkv,S,D] -> flash partials per split:
    acc [B,Hq,ns,Tq,D], m/l [B,Hq,ns,Tq].

    Split-count invariant: the effective split count is
    ``min(n_splits, ceil(S / bk))`` — the cache is PADDED up to a
    ``n_splits * bk`` multiple instead of degrading the split count when
    ``S`` is not block-aligned (prime-ish capacities used to collapse
    split-K parallelism to 1). Padded slots are dead by construction:
    the kernel masks ``slot >= S`` before any position recovery, so the
    padding is invisible to both rolling and non-rolling semantics and
    ``cap`` (the rolling modulus) stays the TRUE capacity ``S``.
    """
    b, hq, tq, d = q.shape
    hkv, s_len = cache_k.shape[1], cache_k.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    bk = min(bk, s_len)
    n_splits = max(1, min(n_splits, -(-s_len // bk)))
    pk = (-s_len) % (n_splits * bk)
    if pk:
        cache_k = jnp.pad(cache_k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        cache_v = jnp.pad(cache_v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    s_pad = s_len + pk
    nk_inner = s_pad // (n_splits * bk)

    clen = jnp.broadcast_to(
        jnp.asarray(cache_len, jnp.int32).reshape(-1), (b,))
    qa = jnp.broadcast_to(
        jnp.asarray(q_abs, jnp.int32).reshape(b, tq), (b, tq)).reshape(-1)

    kernel = functools.partial(
        _phase1_kernel, bk=bk, nk_inner=nk_inner, tq=tq, window=window,
        softcap=attn_softcap, scale=scale, rolling=rolling, cap=s_len)

    out_shape = [
        jax.ShapeDtypeStruct((b, hq, n_splits, tq, d), jnp.float32),
        jax.ShapeDtypeStruct((b, hq, n_splits, tq), jnp.float32),
        jax.ShapeDtypeStruct((b, hq, n_splits, tq), jnp.float32),
    ]
    acc, m, l = pl.pallas_call(
        kernel,
        grid=(b, hq, n_splits, nk_inner),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, tq, d), lambda b_, h, s, j: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, s, j, g=g, nki=nk_inner:
                         (b_, h // g, s * nki + j, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, s, j, g=g, nki=nk_inner:
                         (b_, h // g, s * nki + j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, tq, d),
                         lambda b_, h, s, j: (b_, h, s, 0, 0)),
            pl.BlockSpec((1, 1, 1, tq), lambda b_, h, s, j: (b_, h, s, 0)),
            pl.BlockSpec((1, 1, 1, tq), lambda b_, h, s, j: (b_, h, s, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((tq, d), jnp.float32),
            pltpu.VMEM((tq,), jnp.float32),
            pltpu.VMEM((tq,), jnp.float32),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(clen, qa, q, cache_k, cache_v)
    return acc, m, l


def _merge_with_tree_block(q, blk_k, blk_v, acc, m, l, *, tree_mask,
                           attn_softcap, scale):
    """Shared phase 2: merge phase-1 split partials by log-sum-exp with the
    tree-masked local attention (tiny, T_tree^2 — fp32 jnp)."""
    g = q.shape[1] // blk_k.shape[1]
    # merge splits
    m_g = m.max(axis=2)                                        # [B,Hq,Tq]
    corr = jnp.exp(m - m_g[:, :, None])
    l_g = (l * corr).sum(axis=2)
    acc_g = (acc * corr[..., None]).sum(axis=2)               # [B,Hq,Tq,D]

    # phase 2: tree-local attention
    qf = q.astype(jnp.float32) * scale
    kq = jnp.repeat(blk_k.astype(jnp.float32), g, axis=1)
    vq = jnp.repeat(blk_v.astype(jnp.float32), g, axis=1)
    sc = jnp.einsum("bhqd,bhtd->bhqt", qf, kq)
    if attn_softcap is not None:
        sc = attn_softcap * jnp.tanh(sc / attn_softcap)
    tm = tree_mask
    if tm.ndim == 2:
        tm = tm[None]
    sc = jnp.where(tm[:, None], sc, NEG_INF)
    m_b = sc.max(axis=-1)
    p_b = jnp.exp(sc - m_b[..., None])
    l_b = p_b.sum(axis=-1)
    acc_b = jnp.einsum("bhqt,bhtd->bhqd", p_b, vq)

    m_tot = jnp.maximum(m_g, m_b)
    a1 = jnp.exp(m_g - m_tot)
    a2 = jnp.exp(m_b - m_tot)
    out = (acc_g * a1[..., None] + acc_b * a2[..., None]) / jnp.maximum(
        l_g * a1 + l_b * a2, 1e-30)[..., None]
    return out.astype(q.dtype)


def cascade_attention(q, cache_k, cache_v, blk_k, blk_v, *, cache_len,
                      q_abs, tree_mask, window=None, attn_softcap=None,
                      scale=None, rolling=False, n_splits=8, bk=512,
                      interpret=False):
    """Full cascade verify: phase-1 kernel over the cache + jnp tree-local
    phase-2 + LSE merge.

    q [B,Hq,Tq,D]; cache [B,Hkv,S,D]; blk [B,Hkv,Tb,D];
    tree_mask [B,Tq,Tb] (ancestor mask); returns [B,Hq,Tq,D].
    """
    d = q.shape[-1]
    scale_v = scale if scale is not None else d ** -0.5
    acc, m, l = cascade_phase1(
        q, cache_k, cache_v, cache_len=cache_len, q_abs=q_abs, window=window,
        attn_softcap=attn_softcap, scale=scale_v, rolling=rolling,
        n_splits=n_splits, bk=bk, interpret=interpret)
    return _merge_with_tree_block(q, blk_k, blk_v, acc, m, l,
                                  tree_mask=tree_mask,
                                  attn_softcap=attn_softcap, scale=scale_v)


# ------------------------------------------------------------- paged -------
def _phase1_paged_kernel(pt_ref, cache_len_ref, q_abs_ref, off_ref,  # scalar prefetch
                         q_ref, k_ref, v_ref,                 # VMEM blocks
                         acc_ref, m_ref, l_ref,               # outputs
                         racc, rm, rl,                        # scratch
                         *, page, pos_stride, nk_inner, tq, window, softcap,
                         scale):
    """Identical flash accumulation to ``_phase1_kernel`` with one KV page
    per inner step. The physical page was already resolved by the BlockSpec
    index_map (scalar-prefetched page table), so the body only deals in
    LOGICAL key positions: page ``s*nk_inner + jj`` holds positions
    [base, base+page). Unallocated logical pages surface garbage from a
    clamped pool page and die on the ``kpos < cache_len`` mask, exactly
    like the dense kernel's tail padding.

    ``pos_stride``/``off_ref`` decouple logical positions from the local
    page extent: logical page ``i`` of this buffer covers absolute
    positions ``[i*pos_stride + off, i*pos_stride + off + page)``. The
    single-device engine uses the identity (stride == page, off == 0);
    a kv_seq shard whose pages hold slots ``[ax*page_loc, (ax+1)*page_loc)``
    of every GLOBAL page passes stride=global page size, off=ax*page_loc.
    """
    b = pl.program_id(0)
    s = pl.program_id(2)       # split index
    jj = pl.program_id(3)      # inner page step within the split

    @pl.when(jj == 0)
    def _init():
        racc[...] = jnp.zeros_like(racc)
        rm[...] = jnp.full_like(rm, NEG_INF)
        rl[...] = jnp.zeros_like(rl)

    q = q_ref[0, 0].astype(jnp.float32) * scale              # [tq, D]
    k = k_ref[0, 0].astype(jnp.float32)                      # [page, D]
    v = v_ref[0, 0].astype(jnp.float32)

    sc = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [tq, page]
    if softcap is not None:
        sc = softcap * jnp.tanh(sc / softcap)

    clen = cache_len_ref[b]
    base = (s * nk_inner + jj) * pos_stride + off_ref[0]
    kpos = base + jax.lax.broadcasted_iota(jnp.int32, (page,), 0)
    qpos = q_abs_ref[pl.dslice(b * tq, tq)]                  # [tq]
    qp = qpos[:, None]
    ok = (kpos < clen) & (kpos <= qp)
    if window is not None:
        ok &= kpos > (qp - window)
    sc = jnp.where(ok, sc, NEG_INF)

    m_prev = rm[...]
    m_new = jnp.maximum(m_prev, sc.max(axis=1))
    p = jnp.exp(sc - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    rl[...] = rl[...] * alpha + p.sum(axis=1)
    racc[...] = racc[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    rm[...] = m_new

    @pl.when(jj == nk_inner - 1)
    def _final():
        acc_ref[0, 0, 0] = racc[...]
        m_ref[0, 0, 0] = rm[...]
        l_ref[0, 0, 0] = rl[...]


def cascade_phase1_paged(q, pool_k, pool_v, page_table, *, cache_len, q_abs,
                         window=None, attn_softcap=None, scale=None,
                         n_splits=8, interpret=False, pos_stride=None,
                         pos_offset=None):
    """Split-K flash partials over a PAGED cache.

    q [B,Hq,Tq,D]; pools [P,Hkv,page,D]; page_table [B,max_pages] physical
    page ids (out-of-range entries = unallocated; they are clamped for the
    DMA and masked by ``cache_len``). One grid step streams one page; the
    table is a scalar-prefetch operand so the index_map can address pages
    data-dependently — the TPU analogue of paged attention's block table.
    Returns flash partials acc [B,Hq,ns,Tq,D], m/l [B,Hq,ns,Tq].

    Bytes scale with LIVE length, not capacity: the index_map clamps the
    logical page step to the row's last live page (``cache_len`` is also a
    scalar-prefetch operand, so it is available at index time). Pallas
    elides the DMA when consecutive grid steps resolve to the same block
    index, so the dead tail of the table costs compute on a resident page
    but no additional HBM traffic — the body's ``kpos < cache_len`` mask,
    which works off the UNclamped logical step, still zeroes those scores.

    ``pos_stride`` (static; default = pool page extent) and ``pos_offset``
    (traced scalar; default 0) place logical page ``i`` at absolute
    positions ``i*pos_stride + pos_offset + [0, page)`` — how a kv_seq
    shard attends its non-contiguous slice of every global page
    (``distributed/spdecode.py``).
    """
    b, hq, tq, d = q.shape
    hkv, page = pool_k.shape[1], pool_k.shape[2]
    n_phys = pool_k.shape[0]
    g = hq // hkv
    mp = page_table.shape[-1]
    scale = scale if scale is not None else d ** -0.5
    n_splits = max(1, min(n_splits, mp))
    # keep the requested split count by padding the TABLE (not the pool)
    # with sentinel pages — mirrors the dense kernel's cache padding, so a
    # prime max_pages does not collapse the split-K parallelism. Padded
    # pages clamp to the last physical page and die on the kpos<cache_len
    # mask (their logical positions start at mp*page >= any cache_len).
    pad = (-mp) % n_splits
    page_table = jnp.asarray(page_table, jnp.int32).reshape(-1, mp)
    if pad:
        page_table = jnp.pad(page_table, ((0, 0), (0, pad)),
                             constant_values=n_phys)
        mp = mp + pad
    nk_inner = mp // n_splits

    if pos_stride is None:
        pos_stride = page
    pt = jnp.minimum(page_table, n_phys - 1).reshape(-1)      # [B*MP]
    clen = jnp.broadcast_to(
        jnp.asarray(cache_len, jnp.int32).reshape(-1), (b,))
    qa = jnp.broadcast_to(
        jnp.asarray(q_abs, jnp.int32).reshape(b, tq), (b, tq)).reshape(-1)
    off = jnp.asarray(0 if pos_offset is None else pos_offset,
                      jnp.int32).reshape(-1)[:1]

    kernel = functools.partial(
        _phase1_paged_kernel, page=page, pos_stride=pos_stride,
        nk_inner=nk_inner, tq=tq, window=window, softcap=attn_softcap,
        scale=scale)

    def kv_map(b_, h, s, j, pt_ref, clen_ref, qa_ref, off_ref, g=g,
               nki=nk_inner, mp=mp, stride=pos_stride):
        # Clamp the logical step to the last LIVE page: Pallas elides the
        # DMA when the resolved block index repeats across grid steps, so
        # the dead tail of the table moves no extra bytes. The body masks
        # off the duplicated page's scores via the unclamped kpos.
        step = s * nki + j
        live = (clen_ref[b_] + stride - 1) // stride
        step = jnp.minimum(step, jnp.maximum(live - 1, 0))
        return (pt_ref[b_ * mp + step], h // g, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(b, hq, n_splits, nk_inner),
        in_specs=[
            pl.BlockSpec((1, 1, tq, d),
                         lambda b_, h, s, j, pt_, cl_, qa_, off_:
                         (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, page, d), kv_map),
            pl.BlockSpec((1, 1, page, d), kv_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, tq, d),
                         lambda b_, h, s, j, pt_, cl_, qa_, off_:
                         (b_, h, s, 0, 0)),
            pl.BlockSpec((1, 1, 1, tq),
                         lambda b_, h, s, j, pt_, cl_, qa_, off_:
                         (b_, h, s, 0)),
            pl.BlockSpec((1, 1, 1, tq),
                         lambda b_, h, s, j, pt_, cl_, qa_, off_:
                         (b_, h, s, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((tq, d), jnp.float32),
            pltpu.VMEM((tq,), jnp.float32),
            pltpu.VMEM((tq,), jnp.float32),
        ],
    )
    out_shape = [
        jax.ShapeDtypeStruct((b, hq, n_splits, tq, d), jnp.float32),
        jax.ShapeDtypeStruct((b, hq, n_splits, tq), jnp.float32),
        jax.ShapeDtypeStruct((b, hq, n_splits, tq), jnp.float32),
    ]
    acc, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(pt, clen, qa, off, q, pool_k, pool_v)
    return acc, m, l


def cascade_attention_paged(q, pool_k, pool_v, page_table, blk_k, blk_v, *,
                            cache_len, q_abs, tree_mask, window=None,
                            attn_softcap=None, scale=None, n_splits=8,
                            interpret=False, pos_stride=None,
                            pos_offset=None):
    """Paged cascade verify: page-table phase-1 + shared phase-2 merge.

    Same contract as :func:`cascade_attention` with the long cache given
    as (pool [P,Hkv,page,D], page_table [B,max_pages]) instead of a dense
    [B,Hkv,S,D] buffer; logical key position ``j`` of row ``b`` lives at
    ``pool[page_table[b, j // page], :, j % page]``.
    """
    d = q.shape[-1]
    scale_v = scale if scale is not None else d ** -0.5
    acc, m, l = cascade_phase1_paged(
        q, pool_k, pool_v, page_table, cache_len=cache_len, q_abs=q_abs,
        window=window, attn_softcap=attn_softcap, scale=scale_v,
        n_splits=n_splits, interpret=interpret, pos_stride=pos_stride,
        pos_offset=pos_offset)
    return _merge_with_tree_block(q, blk_k, blk_v, acc, m, l,
                                  tree_mask=tree_mask,
                                  attn_softcap=attn_softcap, scale=scale_v)
