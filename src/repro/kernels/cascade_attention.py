"""Cascade tree-verification attention — the paper's verify op, TPU-native.

One D2SD verification joins K+1 shared-prefix candidates (a comb prefix
tree of T_tree tokens) against a LONG committed KV cache. FlashInfer's GPU
cascade kernel is re-thought for TPU (DESIGN §3):

  phase 1 (this Pallas kernel): the query block (all tree tokens, <= ~128)
    stays resident in VMEM while the kernel sweeps the KV cache HBM->VMEM in
    BlockSpec tiles, split-K over a grid axis so many cache slices progress
    in parallel; each split emits un-normalized flash partials (acc, m, l).
  phase 2 (jnp): partials merge by log-sum-exp with the tree-masked local
    part (tree tokens attending each other via the comb ancestor mask) —
    tiny (T_tree^2), not worth a kernel.

This is also the decode kernel: a chain of 1 token is a degenerate tree.

Masking supports per-example cache lengths (ragged batch), sliding windows
(gemma2/recurrentgemma local layers; rolling-buffer position recovery), and
per-query absolute positions (tree nodes sit at cache_len + depth).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _phase1_kernel(cache_len_ref, q_abs_ref,                  # SMEM
                   q_ref, k_ref, v_ref,                       # VMEM blocks
                   acc_ref, m_ref, l_ref,                     # outputs
                   racc, rm, rl,                              # scratch
                   *, bk, nk_inner, tq, window, softcap, scale, rolling,
                   cap):
    b = pl.program_id(0)
    s = pl.program_id(2)       # split index
    jj = pl.program_id(3)      # inner kv step within the split

    @pl.when(jj == 0)
    def _init():
        racc[...] = jnp.zeros_like(racc)
        rm[...] = jnp.full_like(rm, NEG_INF)
        rl[...] = jnp.zeros_like(rl)

    q = q_ref[0, 0].astype(jnp.float32) * scale              # [tq, D]
    k = k_ref[0, 0].astype(jnp.float32)                      # [bk, D]
    v = v_ref[0, 0].astype(jnp.float32)

    sc = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [tq, bk]
    if softcap is not None:
        sc = softcap * jnp.tanh(sc / softcap)

    clen = cache_len_ref[b]
    base = (s * nk_inner + jj) * bk
    slot = base + jax.lax.broadcasted_iota(jnp.int32, (bk,), 0)
    qpos = q_abs_ref[pl.dslice(b * tq, tq)]                  # [tq]
    qp = qpos[:, None]
    if rolling:
        last = clen - 1
        kpos = last - jax.lax.rem(last - slot, cap)
        ok = (kpos >= 0) & (kpos < clen) & (kpos <= qp)
    else:
        kpos = slot
        ok = (kpos < clen) & (kpos <= qp)
    if window is not None:
        ok &= kpos > (qp - window)
    sc = jnp.where(ok, sc, NEG_INF)

    m_prev = rm[...]
    m_new = jnp.maximum(m_prev, sc.max(axis=1))
    p = jnp.exp(sc - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    rl[...] = rl[...] * alpha + p.sum(axis=1)
    racc[...] = racc[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    rm[...] = m_new

    @pl.when(jj == nk_inner - 1)
    def _final():
        acc_ref[0, 0, 0] = racc[...]
        m_ref[0, 0, 0] = rm[...]
        l_ref[0, 0, 0] = rl[...]


def cascade_phase1(q, cache_k, cache_v, *, cache_len, q_abs, window=None,
                   attn_softcap=None, scale=None, rolling=False,
                   n_splits=8, bk=512, interpret=False):
    """q [B,Hq,Tq,D]; cache [B,Hkv,S,D] -> flash partials per split:
    acc [B,Hq,ns,Tq,D], m/l [B,Hq,ns,Tq]."""
    b, hq, tq, d = q.shape
    hkv, s_len = cache_k.shape[1], cache_k.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    bk = min(bk, s_len)
    n_splits = max(1, min(n_splits, s_len // bk))
    while s_len % (n_splits * bk) and n_splits > 1:
        n_splits -= 1
    pk = (-s_len) % (n_splits * bk)
    if pk:
        cache_k = jnp.pad(cache_k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        cache_v = jnp.pad(cache_v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    s_pad = s_len + pk
    nk_inner = s_pad // (n_splits * bk)

    clen = jnp.broadcast_to(
        jnp.asarray(cache_len, jnp.int32).reshape(-1), (b,))
    qa = jnp.broadcast_to(
        jnp.asarray(q_abs, jnp.int32).reshape(b, tq), (b, tq)).reshape(-1)

    kernel = functools.partial(
        _phase1_kernel, bk=bk, nk_inner=nk_inner, tq=tq, window=window,
        softcap=attn_softcap, scale=scale, rolling=rolling, cap=s_pad)

    out_shape = [
        jax.ShapeDtypeStruct((b, hq, n_splits, tq, d), jnp.float32),
        jax.ShapeDtypeStruct((b, hq, n_splits, tq), jnp.float32),
        jax.ShapeDtypeStruct((b, hq, n_splits, tq), jnp.float32),
    ]
    acc, m, l = pl.pallas_call(
        kernel,
        grid=(b, hq, n_splits, nk_inner),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, tq, d), lambda b_, h, s, j: (b_, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, s, j, g=g, nki=nk_inner:
                         (b_, h // g, s * nki + j, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, s, j, g=g, nki=nk_inner:
                         (b_, h // g, s * nki + j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, tq, d),
                         lambda b_, h, s, j: (b_, h, s, 0, 0)),
            pl.BlockSpec((1, 1, 1, tq), lambda b_, h, s, j: (b_, h, s, 0)),
            pl.BlockSpec((1, 1, 1, tq), lambda b_, h, s, j: (b_, h, s, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((tq, d), jnp.float32),
            pltpu.VMEM((tq,), jnp.float32),
            pltpu.VMEM((tq,), jnp.float32),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(clen, qa, q, cache_k, cache_v)
    return acc, m, l


def cascade_attention(q, cache_k, cache_v, blk_k, blk_v, *, cache_len,
                      q_abs, tree_mask, window=None, attn_softcap=None,
                      scale=None, rolling=False, n_splits=8, bk=512,
                      interpret=False):
    """Full cascade verify: phase-1 kernel over the cache + jnp tree-local
    phase-2 + LSE merge.

    q [B,Hq,Tq,D]; cache [B,Hkv,S,D]; blk [B,Hkv,Tb,D];
    tree_mask [B,Tq,Tb] (ancestor mask); returns [B,Hq,Tq,D].
    """
    b, hq, tq, d = q.shape
    hkv = cache_k.shape[1]
    g = hq // hkv
    scale_v = scale if scale is not None else d ** -0.5
    acc, m, l = cascade_phase1(
        q, cache_k, cache_v, cache_len=cache_len, q_abs=q_abs, window=window,
        attn_softcap=attn_softcap, scale=scale_v, rolling=rolling,
        n_splits=n_splits, bk=bk, interpret=interpret)

    # merge splits
    m_g = m.max(axis=2)                                        # [B,Hq,Tq]
    corr = jnp.exp(m - m_g[:, :, None])
    l_g = (l * corr).sum(axis=2)
    acc_g = (acc * corr[..., None]).sum(axis=2)               # [B,Hq,Tq,D]

    # phase 2: tree-local attention (tiny) in fp32 jnp
    qf = q.astype(jnp.float32) * scale_v
    kq = jnp.repeat(blk_k.astype(jnp.float32), g, axis=1)
    vq = jnp.repeat(blk_v.astype(jnp.float32), g, axis=1)
    sc = jnp.einsum("bhqd,bhtd->bhqt", qf, kq)
    if attn_softcap is not None:
        sc = attn_softcap * jnp.tanh(sc / attn_softcap)
    tm = tree_mask
    if tm.ndim == 2:
        tm = tm[None]
    sc = jnp.where(tm[:, None], sc, NEG_INF)
    m_b = sc.max(axis=-1)
    p_b = jnp.exp(sc - m_b[..., None])
    l_b = p_b.sum(axis=-1)
    acc_b = jnp.einsum("bhqt,bhtd->bhqd", p_b, vq)

    m_tot = jnp.maximum(m_g, m_b)
    a1 = jnp.exp(m_g - m_tot)
    a2 = jnp.exp(m_b - m_tot)
    out = (acc_g * a1[..., None] + acc_b * a2[..., None]) / jnp.maximum(
        l_g * a1 + l_b * a2, 1e-30)[..., None]
    return out.astype(q.dtype)
