"""Pallas TPU flash attention (fwd + bwd) with GQA, causal/window masks,
gemma-style attention-logit softcap, and KV-length masking.

TARGET: TPU (MXU 128x128; VMEM-tiled via BlockSpec). Validated on CPU with
``interpret=True`` against the pure-jnp oracle in ``ref.py``.

Layouts (kernel-internal): q [B, Hq, Tq, D]; k,v [B, Hkv, Tkv, D].
Grid: (B, Hq, nq, nk) — the kv dimension is the minor (sequential) grid axis,
carrying running (m, l, acc) in VMEM scratch across kv steps (the standard
TPU flash schedule). Block sizes default to (128, 128) and are clamped and
padded to hardware-aligned shapes.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _mask_block(qpos, kpos, *, causal, window, kv_len):
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), dtype=bool)
    qp = qpos[:, None]
    kp = kpos[None, :]
    if causal:
        m &= kp <= qp
    if window is not None:
        m &= kp > (qp - window)
    if kv_len is not None:
        m &= kp < kv_len
    return m


def _fwd_kernel(q_off_ref, kv_len_ref, q_ref, k_ref, v_ref,  # inputs
                o_ref, lse_ref,                              # outputs
                acc_ref, m_ref, l_ref,                       # scratch
                *, causal, window, softcap, scale, bq, bk, nk,
                has_kvlen):
    i, j = pl.program_id(2), pl.program_id(3)
    b = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale       # [bq, D]
    k = k_ref[0, 0].astype(jnp.float32)               # [bk, D]
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [bq, bk]
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    q_off = q_off_ref[0]
    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq,), 0) + q_off
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bk,), 0)
    kv_len = kv_len_ref[b] if has_kvlen else None
    mask = _mask_block(qpos, kpos, causal=causal, window=window,
                       kv_len=kv_len)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _final():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0] = m_ref[...] + jnp.log(l)


def flash_attention_fwd(q, k, v, *, causal=True, q_offset=0, window=None,
                        kv_len=None, attn_softcap=None, scale=None,
                        bq=128, bk=128, interpret=False):
    """q [B,Hq,Tq,D]; k,v [B,Hkv,Tkv,D] -> (o [B,Hq,Tq,D], lse [B,Hq,Tq])."""
    b, hq, tq, d = q.shape
    hkv, tkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    bq = min(bq, tq)
    bk = min(bk, tkv)
    # pad to block multiples
    pq = (-tq) % bq
    pk = (-tkv) % bk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nq = (tq + pq) // bq
    nk = (tkv + pk) // bk
    # padded keys masked via kv_len
    eff_kv_len = jnp.full((b,), tkv, jnp.int32) if kv_len is None else \
        jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32).reshape(-1), (b,))
    q_off = jnp.broadcast_to(
        jnp.asarray(q_offset, jnp.int32).reshape(-1), (1,))

    kernel = functools.partial(
        _fwd_kernel, causal=causal, window=window, softcap=attn_softcap,
        scale=scale, bq=bq, bk=bk, nk=nk, has_kvlen=True)

    out_shape = [
        jax.ShapeDtypeStruct(qp.shape, q.dtype),
        jax.ShapeDtypeStruct((b, hq, tq + pq), jnp.float32),
    ]
    o, lse = pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, i, j, g=g: (b_, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, i, j, g=g: (b_, h // g, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda b_, h, i, j: (b_, h, i)),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(q_off, eff_kv_len, qp, kp, vp)
    return o[:, :, :tq], lse[:, :, :tq]


# --------------------------------------------------------------- backward --
def _bwd_dq_kernel(q_off_ref, kv_len_ref, q_ref, k_ref, v_ref, do_ref,
                   lse_ref, delta_ref, dq_ref, dq_acc,
                   *, causal, window, softcap, scale, bq, bk, nk):
    i, j = pl.program_id(2), pl.program_id(3)
    b = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    q = q_ref[0, 0].astype(jnp.float32) * scale
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0]
    delta = delta_ref[0, 0]

    s_raw = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
    if softcap is not None:
        t = jnp.tanh(s_raw / softcap)
        s = softcap * t
        dcap = 1.0 - t * t
    else:
        s = s_raw
        dcap = None
    q_off = q_off_ref[0]
    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq,), 0) + q_off
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bk,), 0)
    mask = _mask_block(qpos, kpos, causal=causal, window=window,
                       kv_len=kv_len_ref[b])
    s = jnp.where(mask, s, NEG_INF)
    p = jnp.exp(s - lse[:, None])
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
    ds = p * (dp - delta[:, None])
    if dcap is not None:
        ds = ds * dcap
    dq_acc[...] += jax.lax.dot_general(ds, k, (((1,), (0,)), ((), ())))

    @pl.when(j == nk - 1)
    def _final():
        dq_ref[0, 0] = (dq_acc[...] * scale).astype(dq_ref.dtype)


def _bwd_dkv_kernel(q_off_ref, kv_len_ref, q_ref, k_ref, v_ref, do_ref,
                    lse_ref, delta_ref, dk_ref, dv_ref, dk_acc, dv_acc,
                    *, causal, window, softcap, scale, bq, bk, nq, g):
    # grid: (B, Hq, nk, nq) — q is the minor axis; dk/dv accumulate per
    # kv block summing over q-heads handled by separate (B, Hq) programs
    # writing into per-head buffers reduced outside for GQA.
    j, i = pl.program_id(2), pl.program_id(3)
    b = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q = q_ref[0, 0].astype(jnp.float32) * scale
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0]
    delta = delta_ref[0, 0]

    s_raw = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))
    if softcap is not None:
        t = jnp.tanh(s_raw / softcap)
        s = softcap * t
        dcap = 1.0 - t * t
    else:
        s = s_raw
        dcap = None
    q_off = q_off_ref[0]
    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq,), 0) + q_off
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bk,), 0)
    mask = _mask_block(qpos, kpos, causal=causal, window=window,
                       kv_len=kv_len_ref[b])
    s = jnp.where(mask, s, NEG_INF)
    p = jnp.exp(s - lse[:, None])                       # [bq, bk]
    dv_acc[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())))
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
    ds = p * (dp - delta[:, None])
    if dcap is not None:
        ds = ds * dcap
    dk_acc[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())))

    @pl.when(i == nq - 1)
    def _final():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


def flash_attention_bwd(q, k, v, o, lse, do, *, causal=True, q_offset=0,
                        window=None, kv_len=None, attn_softcap=None,
                        scale=None, bq=128, bk=128, interpret=False):
    b, hq, tq, d = q.shape
    hkv, tkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    bq = min(bq, tq)
    bk = min(bk, tkv)
    pq = (-tq) % bq
    pk = (-tkv) % bk
    pad4 = lambda x, p: jnp.pad(x, ((0, 0), (0, 0), (0, p), (0, 0)))
    pad3 = lambda x, p, val=0.0: jnp.pad(
        x, ((0, 0), (0, 0), (0, p)), constant_values=val)
    qp, kp2, vp = pad4(q, pq), pad4(k, pk), pad4(v, pk)
    dop = pad4(do, pq)
    # lse padding must keep exp(s - lse) == 0 on padded q rows
    lsep = pad3(lse, pq, 1.0)
    delta = (do.astype(jnp.float32) * o.astype(jnp.float32)).sum(-1)
    deltap = pad3(delta, pq)
    nq = (tq + pq) // bq
    nk = (tkv + pk) // bk
    eff_kv_len = jnp.full((b,), tkv, jnp.int32) if kv_len is None else \
        jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32).reshape(-1), (b,))
    q_off = jnp.broadcast_to(
        jnp.asarray(q_offset, jnp.int32).reshape(-1), (1,))

    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, causal=causal, window=window,
                          softcap=attn_softcap, scale=scale, bq=bq, bk=bk,
                          nk=nk),
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, i, j, g=g: (b_, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, i, j, g=g: (b_, h // g, j, 0)),
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda b_, h, i, j: (b_, h, i)),
            pl.BlockSpec((1, 1, bq), lambda b_, h, i, j: (b_, h, i)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda b_, h, i, j: (b_, h, i, 0)),
        scratch_shapes=[pltpu.VMEM((bq, d), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct(qp.shape, q.dtype),
        interpret=interpret,
    )(q_off, eff_kv_len, qp, kp2, vp, dop, lsep, deltap)

    # dk/dv per q-head, then reduce over the GQA group
    dk_h, dv_h = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, causal=causal, window=window,
                          softcap=attn_softcap, scale=scale, bq=bq, bk=bk,
                          nq=nq, g=g),
        grid=(b, hq, nk, nq),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, j, i: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, j, i, g=g: (b_, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h, j, i, g=g: (b_, h // g, j, 0)),
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, j, i: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, bq), lambda b_, h, j, i: (b_, h, i)),
            pl.BlockSpec((1, 1, bq), lambda b_, h, j, i: (b_, h, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, j, i: (b_, h, j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, j, i: (b_, h, j, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((bk, d), jnp.float32),
                        pltpu.VMEM((bk, d), jnp.float32)],
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, tkv + pk, d), jnp.float32),
            jax.ShapeDtypeStruct((b, hq, tkv + pk, d), jnp.float32),
        ],
        interpret=interpret,
    )(q_off, eff_kv_len, qp, kp2, vp, dop, lsep, deltap)
    dk = dk_h.reshape(b, hkv, g, tkv + pk, d).sum(2)[:, :, :tkv]
    dv = dv_h.reshape(b, hkv, g, tkv + pk, d).sum(2)[:, :, :tkv]
    return dq[:, :, :tq], dk.astype(k.dtype), dv.astype(v.dtype)
