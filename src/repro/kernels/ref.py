"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are the ground truth the kernels are allclose-tested against in
interpret mode, sweeping shapes and dtypes (tests/test_kernels.py).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _mask(b, tq, tkv, *, causal, q_offset, window, kv_len):
    q_off = jnp.broadcast_to(jnp.asarray(q_offset).reshape(-1), (b,))
    qpos = jnp.arange(tq)[None, :, None] + q_off[:, None, None]
    kpos = jnp.arange(tkv)[None, None, :]
    m = jnp.ones((b, tq, tkv), bool)
    if causal:
        m &= kpos <= qpos
    if window is not None:
        m &= kpos > (qpos - window)
    if kv_len is not None:
        kl = jnp.broadcast_to(jnp.asarray(kv_len).reshape(-1), (b,))
        m &= kpos < kl[:, None, None]
    return m


def flash_attention_ref(q, k, v, *, causal=True, q_offset=0, window=None,
                        kv_len=None, attn_softcap=None, scale=None):
    """q [B,Hq,Tq,D]; k,v [B,Hkv,Tkv,D] -> (o, lse)."""
    b, hq, tq, d = q.shape
    hkv, tkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    kq = jnp.repeat(k, g, axis=1).astype(jnp.float32)
    vq = jnp.repeat(v, g, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale, kq)
    if attn_softcap is not None:
        s = attn_softcap * jnp.tanh(s / attn_softcap)
    m = _mask(b, tq, tkv, causal=causal, q_offset=q_offset, window=window,
              kv_len=kv_len)
    s = jnp.where(m[:, None], s, NEG_INF)
    mx = s.max(-1)
    p = jnp.exp(s - mx[..., None])
    l = p.sum(-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p / jnp.maximum(l, 1e-30)[..., None],
                   vq)
    return o.astype(q.dtype), mx + jnp.log(jnp.maximum(l, 1e-30))


def cascade_attention_ref(q, cache_k, cache_v, blk_k, blk_v, *, cache_len,
                          q_abs, tree_mask, window=None, attn_softcap=None,
                          scale=None, rolling=False):
    """Single-softmax oracle over [cache ++ tree block] with absolute-
    position masking identical to the kernel's semantics."""
    b, hq, tq, d = q.shape
    hkv, s_len = cache_k.shape[1], cache_k.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    clen = jnp.broadcast_to(jnp.asarray(cache_len).reshape(-1), (b,))
    qa = jnp.broadcast_to(jnp.asarray(q_abs).reshape(b, tq), (b, tq))

    kq = jnp.concatenate([cache_k, blk_k], axis=2)
    vq = jnp.concatenate([cache_v, blk_v], axis=2)
    kq = jnp.repeat(kq, g, axis=1).astype(jnp.float32)
    vq = jnp.repeat(vq, g, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale, kq)
    if attn_softcap is not None:
        s = attn_softcap * jnp.tanh(s / attn_softcap)

    # cache mask (absolute positions, rolling-aware)
    slot = jnp.arange(s_len)[None, None, :]
    qp = qa[:, :, None]
    cl = clen[:, None, None]
    if rolling:
        last = cl - 1
        kpos = last - jnp.mod(last - slot, s_len)
        ok_c = (kpos >= 0) & (kpos < cl) & (kpos <= qp)
    else:
        kpos = slot
        ok_c = (kpos < cl) & (kpos <= qp)
    if window is not None:
        ok_c &= kpos > (qp - window)
    tm = tree_mask if tree_mask.ndim == 3 else jnp.broadcast_to(
        tree_mask[None], (b, tq, blk_k.shape[2]))
    full = jnp.concatenate([ok_c, tm], axis=-1)
    s = jnp.where(full[:, None], s, NEG_INF)
    mx = s.max(-1)
    p = jnp.exp(s - mx[..., None])
    o = jnp.einsum("bhqk,bhkd->bhqd",
                   p / jnp.maximum(p.sum(-1), 1e-30)[..., None], vq)
    return o.astype(q.dtype)


def gather_pages(pool, page_table):
    """Materialize the logical [B,Hkv,MP*page,D] cache view of a page pool
    [P,Hkv,page,D] (kernel layout). Out-of-range table entries clamp to
    the last physical page; the garbage they surface lies at logical
    positions >= cache_len and is masked by the attention semantics."""
    n_phys = pool.shape[0]
    pt = jnp.clip(jnp.asarray(page_table, jnp.int32), 0, n_phys - 1)
    v = pool[pt]                                   # [B, MP, Hkv, page, D]
    b, mp, hkv, page, d = v.shape
    return jnp.moveaxis(v, 2, 1).reshape(b, hkv, mp * page, d)


def cascade_attention_paged_ref(q, pool_k, pool_v, page_table, blk_k, blk_v,
                                *, cache_len, q_abs, tree_mask, window=None,
                                attn_softcap=None, scale=None):
    """Oracle for the paged cascade kernel: gather the logical view, then
    run the dense cascade oracle on it (paged indexing changes only WHERE
    keys live, never the attention semantics)."""
    return cascade_attention_ref(
        q, gather_pages(pool_k, page_table), gather_pages(pool_v, page_table),
        blk_k, blk_v, cache_len=cache_len, q_abs=q_abs, tree_mask=tree_mask,
        window=window, attn_softcap=attn_softcap, scale=scale,
        rolling=False)
