"""Sharded, elastic, integrity-checked checkpointing.

Layout on disk::

    <dir>/step_000123/
        manifest.json      # tree structure, shapes, dtypes, sha256 per file
        shard_<proc>.npz   # this process's addressable data, one entry per
                           # leaf path ('/'-joined)

Design points for 1000+ nodes:
  * each process writes only its addressable shards (here: single-process
    container => full arrays; the addressing logic goes through
    ``jax.experimental.multihost_utils``-free code paths that degrade to
    local-only gracefully);
  * ELASTIC restore: the manifest stores the *logical* tree; restore takes a
    target mesh + sharding rules and ``jax.device_put``s each leaf with its
    rule-derived NamedSharding — the saved mesh does NOT need to match the
    restore mesh (scale up/down across restarts);
  * async save: a background thread serializes a host copy so the train loop
    continues; ``wait()`` joins before the next save (bounded staleness 1);
  * integrity: sha256 over every npz entry recorded in the manifest and
    verified on load.
"""
from __future__ import annotations

import hashlib
import io
import json
import os
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        parts = []
        for k in kp:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        flat["/".join(parts)] = leaf
    return flat


class Checkpointer:
    def __init__(self, directory: str, async_save: bool = False):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- save ---
    def save(self, step: int, tree, extra: Optional[Dict] = None):
        self.wait()
        host = jax.tree.map(lambda x: np.asarray(x), tree)
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write, args=(step, host, tree, extra),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, host, tree, extra)

    def _write(self, step: int, host_tree, orig_tree, extra):
        out = self.dir / f"step_{step:08d}"
        tmp = self.dir / f".tmp_step_{step:08d}"
        tmp.mkdir(parents=True, exist_ok=True)
        flat = _flatten(host_tree)
        shard_file = tmp / "shard_0.npz"
        np.savez(shard_file, **{k: v for k, v in flat.items()})
        sha = hashlib.sha256(shard_file.read_bytes()).hexdigest()
        treedef = jax.tree.structure(orig_tree)
        manifest = {
            "step": step,
            "leaves": {k: {"shape": list(np.shape(v)),
                           "dtype": str(np.asarray(v).dtype)}
                       for k, v in flat.items()},
            "treedef": str(treedef),
            "files": {"shard_0.npz": sha},
            "extra": extra or {},
            "time": time.time(),
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        if out.exists():
            import shutil
            shutil.rmtree(out)
        tmp.rename(out)          # atomic publish

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ---------------------------------------------------------- restore ---
    def latest_step(self) -> Optional[int]:
        steps = sorted(int(p.name.split("_")[1]) for p in self.dir.glob(
            "step_*") if p.is_dir())
        return steps[-1] if steps else None

    def restore(self, like_tree, step: Optional[int] = None,
                mesh=None, verify: bool = True):
        """Restore into the structure of ``like_tree``. With ``mesh``, each
        leaf is device_put with its rule-derived sharding (elastic)."""
        step = step if step is not None else self.latest_step()
        assert step is not None, f"no checkpoints in {self.dir}"
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        shard_file = d / "shard_0.npz"
        if verify:
            sha = hashlib.sha256(shard_file.read_bytes()).hexdigest()
            assert sha == manifest["files"]["shard_0.npz"], \
                "checkpoint corrupted (sha mismatch)"
        data = np.load(shard_file)
        flat_like = _flatten(like_tree)
        vals = {}
        for k in flat_like:
            assert k in data, f"missing leaf {k} in checkpoint"
            vals[k] = data[k]
        leaves_like, treedef = jax.tree_util.tree_flatten(like_tree)
        keys = list(_flatten(like_tree).keys())
        restored_flat = [vals[k] for k in keys]
        tree = jax.tree_util.tree_unflatten(treedef, restored_flat)
        if mesh is not None:
            from repro.distributed.sharding import params_shardings
            shardings = params_shardings(tree, mesh)
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        else:
            tree = jax.tree.map(
                lambda x, l: jax.numpy.asarray(
                    x, getattr(l, "dtype", None)), tree, like_tree)
        return tree, manifest.get("extra", {})
