"""Sharded optimizers: AdamW, AdamW with int8-quantized moments (state
compression — a distributed-optimization trick that cuts optimizer HBM 4x),
and Adafactor (factored second moment, for the 1T-param MoE).

All are functional: ``init(params) -> state``, ``update(grads, state, params,
step, hp) -> (new_params, new_state)``. Optimizer states inherit the
parameter sharding (same tree paths -> same logical axes), so ZeRO-style
sharding falls out of the parameter rules.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import OptimizerConfig


# ------------------------------------------------------------- schedules ---
def lr_schedule(hp: OptimizerConfig, step):
    warm = jnp.minimum(step / jnp.maximum(hp.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - hp.warmup_steps)
                    / jnp.maximum(hp.total_steps - hp.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return hp.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


# ------------------------------------------------------- int8 moment util --
_Q8_BLOCK = 256


def _q8(x):
    """Symmetric BLOCK-WISE int8 quantization (bitsandbytes-style): the
    second moment spans many orders of magnitude within a tensor, so scales
    are per 256-element block, not per tensor."""
    flat = x.reshape(-1)
    pad = (-flat.size) % _Q8_BLOCK
    fp = jnp.pad(flat, (0, pad)).reshape(-1, _Q8_BLOCK)
    amax = jnp.max(jnp.abs(fp), axis=1, keepdims=True) + 1e-12
    scale = (amax / 127.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(fp / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def _dq8(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


# ------------------------------------------------------------------ AdamW --
def adamw_init(params, quantized: bool = False):
    def zero_like(p):
        if quantized:
            nblk = (p.size + _Q8_BLOCK - 1) // _Q8_BLOCK
            return {"q": jnp.zeros((nblk, _Q8_BLOCK), jnp.int8),
                    "s": jnp.zeros((nblk,), jnp.float32)}
        return jnp.zeros(p.shape, jnp.float32)

    return {"m": jax.tree.map(zero_like, params),
            "v": jax.tree.map(zero_like, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(grads, state, params, hp: OptimizerConfig,
                 quantized: bool = False):
    step = state["step"] + 1
    lr = lr_schedule(hp, step)
    grads, gn = clip_by_global_norm(grads, hp.grad_clip)
    b1, b2, eps = hp.b1, hp.b2, hp.eps
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    is_leaf = (lambda x: isinstance(x, dict) and "q" in x) if quantized else None

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        if quantized:
            m_f = _dq8(m["q"], m["s"], p.shape)
            # v stored in sqrt domain (halves the dynamic range an int8
            # linear code must span — cf. bitsandbytes' dynamic map)
            v_f = jnp.square(_dq8(v["q"], v["s"], p.shape))
        else:
            m_f, v_f = m, v
        m_new = b1 * m_f + (1 - b1) * g
        v_new = b2 * v_f + (1 - b2) * jnp.square(g)
        upd_ = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
        if quantized:
            # quantization can zero tiny v entries whose m survived; bound
            # the per-entry step like bitsandbytes' max_unorm
            upd_ = jnp.clip(upd_, -3.0, 3.0)
        p_new = (p.astype(jnp.float32)
                 - lr * (upd_ + hp.weight_decay * p.astype(jnp.float32)))
        if quantized:
            mq, ms = _q8(m_new)
            vq, vs = _q8(jnp.sqrt(v_new))
            return p_new.astype(p.dtype), {"q": mq, "s": ms}, {"q": vq, "s": vs}
        return p_new.astype(p.dtype), m_new, v_new

    flat_p = jax.tree.leaves(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"], is_leaf=is_leaf)
    flat_v = jax.tree.leaves(state["v"], is_leaf=is_leaf)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    treedef = jax.tree.structure(params)
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"lr": lr, "grad_norm": gn}


# -------------------------------------------------------------- Adafactor --
def adafactor_init(params):
    def factored(p):
        if p.ndim >= 2:
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {"v": jax.tree.map(factored, params),
            "step": jnp.zeros((), jnp.int32)}


def adafactor_update(grads, state, params, hp: OptimizerConfig):
    step = state["step"] + 1
    lr = lr_schedule(hp, step)
    grads, gn = clip_by_global_norm(grads, hp.grad_clip)
    decay = 1.0 - step.astype(jnp.float32) ** -0.8
    eps = 1e-30

    def upd(p, g, v):
        g = g.astype(jnp.float32)
        g2 = jnp.square(g) + eps
        if p.ndim >= 2:
            vr = decay * v["vr"] + (1 - decay) * g2.mean(-1)
            vc = decay * v["vc"] + (1 - decay) * g2.mean(-2)
            denom = (vr[..., None] * vc[..., None, :]
                     / jnp.maximum(vr.mean(-1)[..., None, None], eps))
            u = g * jax.lax.rsqrt(jnp.maximum(denom, eps))
            v_new = {"vr": vr, "vc": vc}
        else:
            vv = decay * v["v"] + (1 - decay) * g2
            u = g * jax.lax.rsqrt(jnp.maximum(vv, eps))
            v_new = {"v": vv}
        # update clipping (Adafactor d=1.0)
        rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
        u = u / jnp.maximum(1.0, rms_u)
        p_new = (p.astype(jnp.float32)
                 - lr * (u + hp.weight_decay * p.astype(jnp.float32)))
        return p_new.astype(p.dtype), v_new

    leaf = lambda x: isinstance(x, dict) and ("vr" in x or "v" in x)
    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_v = jax.tree.leaves(state["v"], is_leaf=leaf)
    out = [upd(p, g, v) for p, g, v in zip(flat_p, flat_g, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[1] for o in out])
    return new_p, {"v": new_v, "step": step}, {"lr": lr, "grad_norm": gn}


# ------------------------------------------------------------- dispatcher --
def make_optimizer(hp: OptimizerConfig):
    if hp.name == "adamw":
        return (lambda p: adamw_init(p, False),
                lambda g, s, p: adamw_update(g, s, p, hp, False))
    if hp.name == "adamw8bit":
        return (lambda p: adamw_init(p, True),
                lambda g, s, p: adamw_update(g, s, p, hp, True))
    if hp.name == "adafactor":
        return (adafactor_init, lambda g, s, p: adafactor_update(g, s, p, hp))
    raise ValueError(hp.name)
