"""Training loop with checkpoint/restart, failure injection, and a
straggler monitor (assignment: fault tolerance).

The loop is deliberately framework-shaped: a pure jitted ``step_fn``, a
checkpointable data iterator, a Checkpointer, and a restart wrapper that
resumes from the latest checkpoint after a (simulated or real) failure.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.config.base import TrainConfig


class InjectedFailure(RuntimeError):
    """Raised by tests to simulate a node failure mid-run."""


@dataclasses.dataclass
class StragglerMonitor:
    """Tracks per-step wall time; flags outliers. At real scale the flag
    feeds pod-level re-meshing (documented in DESIGN §4); here it records
    and exposes the decision signal."""
    window: int = 50
    threshold: float = 3.0
    times: List[float] = dataclasses.field(default_factory=list)
    flagged: List[int] = dataclasses.field(default_factory=list)

    def record(self, step: int, dt: float):
        self.times.append(dt)
        hist = self.times[-self.window:-1]
        if len(hist) >= 10 and dt > self.threshold * float(np.median(hist)):
            self.flagged.append(step)
            return True
        return False


def train(step_fn: Callable, state: Dict[str, Any], dataset,
          tc: TrainConfig, *, hooks: Optional[Dict[str, Callable]] = None,
          ckpt: Optional[Checkpointer] = None,
          log: Callable = print) -> Dict[str, Any]:
    """Run ``tc.optimizer.total_steps`` steps with checkpoint + restart.

    state: dict with at least {params, opt_state, step:int}.
    step_fn(params, opt_state, batch) -> (params, opt_state, metrics).
    hooks: {"pre_step": fn(step) -> None} — tests inject failures here.
    """
    hooks = hooks or {}
    ckpt = ckpt or Checkpointer(tc.checkpoint_dir,
                                async_save=tc.async_checkpoint)
    monitor = StragglerMonitor()
    restarts = 0
    metrics_hist: List[Dict] = []

    while True:
        try:
            while state["step"] < tc.optimizer.total_steps:
                step = state["step"]
                if "pre_step" in hooks:
                    hooks["pre_step"](step)
                t0 = time.time()
                batch = dataset.next_batch()
                params, opt_state, metrics = step_fn(
                    state["params"], state["opt_state"], batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.time() - t0
                state["params"], state["opt_state"] = params, opt_state
                state["step"] = step + 1
                slow = monitor.record(step, dt)
                if step % tc.log_every == 0:
                    log(f"step {step} loss {float(metrics['loss']):.4f} "
                        f"({dt * 1e3:.0f} ms{' STRAGGLER' if slow else ''})")
                metrics_hist.append(
                    {k: float(v) for k, v in metrics.items()})
                if (step + 1) % tc.checkpoint_every == 0:
                    ckpt.save(step + 1,
                              {"params": state["params"],
                               "opt_state": state["opt_state"]},
                              extra={"step": step + 1,
                                     "data": dataset.state_dict()})
            break
        except InjectedFailure as e:
            restarts += 1
            if restarts > tc.max_restarts:
                raise
            log(f"FAILURE at step {state['step']}: {e}; restarting "
                f"({restarts}/{tc.max_restarts})")
            ckpt.wait()
            last = ckpt.latest_step()
            if last is not None:
                restored, extra = ckpt.restore(
                    {"params": state["params"],
                     "opt_state": state["opt_state"]})
                state["params"] = restored["params"]
                state["opt_state"] = restored["opt_state"]
                state["step"] = int(extra["step"])
                dataset.load_state_dict(extra["data"])
            else:
                state["step"] = 0

    ckpt.wait()
    return {"state": state, "metrics": metrics_hist,
            "stragglers": monitor.flagged, "restarts": restarts}
