"""One-shot empirical study driver: pretrain target, distill all drafters,
save artifacts for the benchmark suite.

    PYTHONPATH=src python -m repro.training.run_study [--fast]

Artifacts land in experiments/study/ (checkpoints + metadata); benchmarks
load them instead of retraining.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.paper_target import drafter_small, smoke
from repro.core.drafter import DrafterConfig
from repro.data.synthetic import SyntheticDataset, TASKS
from repro.training import distill

STUDY_DIR = Path(__file__).resolve().parents[3] / "experiments" / "study"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--target-steps", type=int, default=240)
    ap.add_argument("--drafter-steps", type=int, default=400)
    ap.add_argument("--gamma", type=int, default=16)
    ap.add_argument("--rollouts-per-task", type=int, default=48)
    ap.add_argument("--rollout-new", type=int, default=160)
    args = ap.parse_args()
    if args.fast:
        args.target_steps, args.drafter_steps = 60, 80
        args.rollouts_per_task, args.rollout_new = 8, 48

    STUDY_DIR.mkdir(parents=True, exist_ok=True)
    tcfg = smoke()
    t_all = time.time()

    # 1. pretrain target ----------------------------------------------------
    print("== pretraining target ==")
    tparams, tmetrics = distill.pretrain_target(
        tcfg, steps=args.target_steps, batch=24, seq_len=160)
    print(f"target final loss {tmetrics[-1]['loss']:.4f}")

    # 2. rollouts ------------------------------------------------------------
    print("== generating target rollouts ==")
    rolls = []
    for task in TASKS:
        ds = SyntheticDataset(task, 1, 64, seed=123)
        prompts = ds.prompts(args.rollouts_per_task, 32)
        r = distill.generate_rollouts(tparams, tcfg, prompts,
                                      args.rollout_new)
        rolls.append(r)
    rollouts = np.concatenate(rolls, axis=0)
    print(f"rollouts: {rollouts.shape}")

    # 3. drafters ------------------------------------------------------------
    dcfg = drafter_small(gamma=args.gamma)
    print("== training DFlash drafter (first draft) ==")
    d1, l1 = distill.train_drafter(dcfg, tparams, tcfg, rollouts, vp=False,
                                   steps=args.drafter_steps, batch=24)
    print("== training VP-Drafter (Eq. 6/7 recipe) ==")
    d2, l2 = distill.train_drafter(dcfg, tparams, tcfg, rollouts, vp=True,
                                   steps=args.drafter_steps, batch=24)
    print("== training EAGLE-style AR baseline drafter ==")
    dcfg_ar = drafter_small(gamma=args.gamma, causal=True)
    dar, l3 = distill.train_drafter(dcfg_ar, tparams, tcfg, rollouts,
                                    vp=False, causal=True,
                                    steps=args.drafter_steps, batch=24)

    # 4. save ---------------------------------------------------------------
    ck = Checkpointer(str(STUDY_DIR / "ckpt"))
    ck.save(1, {"target": tparams, "d1": d1, "d2": d2, "ar": dar},
            extra={"gamma": args.gamma,
                   "target_loss": float(tmetrics[-1]["loss"]),
                   "drafter_losses": {"dflash": l1[-1], "vp": l2[-1],
                                      "ar": l3[-1]}})
    meta = {"gamma": args.gamma, "target_steps": args.target_steps,
            "drafter_steps": args.drafter_steps,
            "rollouts": list(rollouts.shape),
            "wall_min": round((time.time() - t_all) / 60, 1)}
    (STUDY_DIR / "meta.json").write_text(json.dumps(meta, indent=2))
    print(f"saved study artifacts to {STUDY_DIR} "
          f"({meta['wall_min']} min)")


def load_study():
    """Load (tcfg, dcfg, params dict, meta) saved by main()."""
    tcfg = smoke()
    meta = json.loads((STUDY_DIR / "meta.json").read_text())
    gamma = meta["gamma"]
    dcfg = drafter_small(gamma=gamma)
    dcfg_ar = drafter_small(gamma=gamma, causal=True)
    ck = Checkpointer(str(STUDY_DIR / "ckpt"))
    import jax.numpy as jnp
    from repro.core.drafter import drafter_init
    from repro.models import lm
    like = {
        "target": jax.eval_shape(lambda: lm.lm_init(jax.random.PRNGKey(0),
                                                    tcfg)),
        "d1": jax.eval_shape(lambda: drafter_init(jax.random.PRNGKey(0),
                                                  dcfg)),
        "d2": jax.eval_shape(lambda: drafter_init(jax.random.PRNGKey(0),
                                                  dcfg)),
        "ar": jax.eval_shape(lambda: drafter_init(jax.random.PRNGKey(0),
                                                  dcfg_ar)),
    }
    params, extra = ck.restore(like)
    return tcfg, dcfg, dcfg_ar, params, meta


if __name__ == "__main__":
    main()
