"""Synthetic task suites mirroring the paper's benchmark categories.

The paper evaluates on Math (GSM8K/MATH), Code (HumanEval/MBPP) and Chat
(MT-Bench/Alpaca). At CPU scale we mirror the *statistical structure* that
drives speculative-decoding behaviour: math/code have low-entropy, highly
structured continuations (high draft acceptance); chat is high-entropy
(diffuse boundary posterior) — exactly the gradient Table 3 shows.

  math: chained 2-3 digit additions  "12+34=46;46+7=53;..."
  code: bracket/keyword PCFG         "def f1(x): return (x+3)*f0(x) ..."
  chat: order-2 Markov babble with topic tokens (high entropy)

All generators are deterministic in (seed, index) and pure numpy.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

VOCAB = 512
PAD, BOS, EOS = 0, 1, 2
_CHARS = "0123456789+-*/=;()abcdefghijklmnopqrstuvwxyz_ :.,!?\n"
CHAR_TO_ID = {c: i + 3 for i, c in enumerate(_CHARS)}
ID_TO_CHAR = {i: c for c, i in CHAR_TO_ID.items()}


def encode(s: str) -> List[int]:
    return [CHAR_TO_ID.get(c, CHAR_TO_ID[" "]) for c in s]


def decode_ids(ids) -> str:
    return "".join(ID_TO_CHAR.get(int(i), "#") for i in ids)


def gen_math(rng: np.random.Generator, seq_len: int) -> np.ndarray:
    toks = [BOS]
    a = int(rng.integers(10, 99))
    while len(toks) < seq_len + 1:
        b = int(rng.integers(2, 99))
        c = a + b
        toks.extend(encode(f"{a}+{b}={c};"))
        a = c if c < 800 else int(rng.integers(10, 99))
    return np.array(toks[: seq_len + 1], np.int32)


def gen_code(rng: np.random.Generator, seq_len: int) -> np.ndarray:
    toks = [BOS]
    fn = 0
    while len(toks) < seq_len + 1:
        k = int(rng.integers(1, 9))
        op = "+-*"[int(rng.integers(0, 3))]
        body = f"def f{fn}(x): return (x{op}{k})*f{max(fn - 1, 0)}(x)\n"
        toks.extend(encode(body))
        fn += 1
    return np.array(toks[: seq_len + 1], np.int32)


_TOPICS = ["the cat", "a model", "my friend", "the sky", "this code",
           "a dream", "the city"]
_VERBS = ["likes", "sees", "wants", "finds", "breaks", "makes", "knows"]
_OBJS = ["the sun", "a book", "fast cars", "hot tea", "old songs",
         "new ideas", "the rain", "long walks"]


def gen_chat(rng: np.random.Generator, seq_len: int) -> np.ndarray:
    toks = [BOS]
    while len(toks) < seq_len + 1:
        s = (f"{_TOPICS[rng.integers(len(_TOPICS))]} "
             f"{_VERBS[rng.integers(len(_VERBS))]} "
             f"{_OBJS[rng.integers(len(_OBJS))]}")
        if rng.random() < 0.4:
            s += f" and {_OBJS[rng.integers(len(_OBJS))]}"
        toks.extend(encode(s + ". "))
    return np.array(toks[: seq_len + 1], np.int32)


GENERATORS = {"math": gen_math, "code": gen_code, "chat": gen_chat}
TASKS = tuple(GENERATORS)


@dataclasses.dataclass
class DataState:
    """Checkpointable iterator state (exact resume)."""
    seed: int
    step: int = 0


class SyntheticDataset:
    """Deterministic, shardable, checkpointable batch source."""

    def __init__(self, task: str, batch: int, seq_len: int, seed: int = 0,
                 shard_id: int = 0, num_shards: int = 1,
                 mixture: Optional[Dict[str, float]] = None):
        self.task = task
        self.batch = batch
        self.seq_len = seq_len
        self.state = DataState(seed=seed)
        self.shard_id = shard_id
        self.num_shards = num_shards
        self.mixture = mixture

    def _gen_one(self, idx: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.state.seed, idx]))
        if self.task == "mixture":
            names = list((self.mixture or
                          {t: 1 / len(TASKS) for t in TASKS}))
            probs = np.array([self.mixture[n] for n in names]) \
                if self.mixture else None
            t = rng.choice(names, p=probs)
            return GENERATORS[t](rng, self.seq_len)
        return GENERATORS[self.task](rng, self.seq_len)

    def next_batch(self) -> Dict[str, np.ndarray]:
        base = (self.state.step * self.num_shards + self.shard_id) \
            * self.batch
        seqs = np.stack([self._gen_one(base + i) for i in range(self.batch)])
        self.state.step += 1
        return {
            "tokens": seqs[:, :-1].astype(np.int32),
            "labels": seqs[:, 1:].astype(np.int32),
            "mask": (seqs[:, 1:] != PAD).astype(np.float32),
        }

    def prompts(self, n: int, prompt_len: int, offset: int = 10 ** 6
                ) -> np.ndarray:
        out = np.stack([self._gen_one(offset + i)[: prompt_len]
                        for i in range(n)])
        return out.astype(np.int32)

    # --- checkpointing ---
    def state_dict(self) -> Dict:
        return {"seed": self.state.seed, "step": self.state.step}

    def load_state_dict(self, d: Dict):
        self.state = DataState(seed=int(d["seed"]), step=int(d["step"]))
