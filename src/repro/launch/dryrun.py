import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (assignment deliverable e).

Lowers + compiles every (architecture x input-shape) cell against the
production meshes (single-pod 16x16 = 256 chips; multi-pod 2x16x16 = 512)
and records memory analysis, loop-aware FLOP/collective counts, and the
three roofline terms per cell into experiments/dryrun/<cell>.json.

Run one cell:   python -m repro.launch.dryrun --arch qwen2.5-3b \
                    --shape train_4k --mesh single
Run everything: python -m repro.launch.dryrun --all --jobs 4
"""

import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _cell_name(arch, shape, mesh):
    return f"{arch}_{shape}_{mesh}".replace("/", "-")


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             gamma: int = 16, k_branches: int = 4,
             loss_seq_chunk=None, remat_policy=None,
             tag: str = "", diagnose: bool = False,
             rules_override: dict = None, fsdp_override=None) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.config.base import shape_by_name
    from repro.config.registry import get_config
    from repro.distributed import sharding as sh
    from repro.launch import steps as steps_lib
    from repro.launch.mesh import make_production_mesh
    from repro.roofline import analysis as roof
    from repro.roofline.hlo_analysis import analyze_hlo_text

    t0 = time.time()
    multi = mesh_kind == "multi"
    n_chips = 512 if multi else 256
    devices = jax.devices()[:n_chips]
    from repro.distributed.compat import make_mesh as compat_make_mesh
    mesh = compat_make_mesh(
        (2, 16, 16) if multi else (16, 16),
        ("pod", "data", "model") if multi else ("data", "model"),
        devices=devices)

    cell = steps_lib.build_cell(arch, shape_name, gamma=gamma,
                                k_branches=k_branches,
                                loss_seq_chunk=loss_seq_chunk,
                                remat_policy=remat_policy)
    if cell is None:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "skipped": "long_500k requires sub-quadratic attention",
                "ok": True}

    cfg = get_config(arch)
    shape = shape_by_name(shape_name)
    rules = dict(cell.rules)
    if rules_override:
        rules.update(rules_override)
    fsdp = cell.fsdp if fsdp_override is None else fsdp_override

    with sh.use_sharding(mesh, rules, fsdp=fsdp):
        in_shardings = sh.params_shardings(cell.args, mesh)
        jitted = jax.jit(cell.fn, in_shardings=in_shardings)
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        hlo_text = compiled.as_text()
        hlo_stats = analyze_hlo_text(hlo_text)
        top_colls = None
        if diagnose:
            from repro.roofline.hlo_analysis import top_collectives
            top_colls = top_collectives(hlo_text, k=15)

    # ---- roofline terms ----
    # memory_analysis sizes are per-device (post-SPMD program)
    arg_bytes_dev = getattr(mem, "argument_size_in_bytes", 0)
    temp_bytes_dev = getattr(mem, "temp_size_in_bytes", 0)
    out_bytes_dev = getattr(mem, "output_size_in_bytes", 0)

    if cell.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        opt_bytes_dev = arg_bytes_dev * 0.5    # rough: opt state share
        hbm = roof.analytic_hbm_bytes(cfg, shape, "train", n_chips,
                                      arg_bytes_dev * 0.4,
                                      opt_bytes_per_dev=opt_bytes_dev)
    elif cell.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        hbm = roof.analytic_hbm_bytes(cfg, shape, "prefill", n_chips,
                                      arg_bytes_dev * 0.5,
                                      state_bytes_per_dev=arg_bytes_dev * 0.3)
    else:
        gamma_tok = gamma + k_branches * (gamma - 1)
        tokens = shape.global_batch * gamma_tok
        # decode traffic: params + the KV/feature caches actually read
        hbm = roof.analytic_hbm_bytes(cfg, shape, "decode", n_chips,
                                      arg_bytes_dev * 0.4,
                                      state_bytes_per_dev=arg_bytes_dev * 0.5,
                                      spec_overhead=3.0)
    terms = roof.derive_terms(cfg, shape, cell.kind, n_chips, hlo_stats,
                              hbm, tokens)

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "kind": cell.kind, "ok": True, "chips": n_chips,
        "tag": tag,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes_per_dev": int(arg_bytes_dev),
            "temp_bytes_per_dev": int(temp_bytes_dev),
            "output_bytes_per_dev": int(out_bytes_dev),
            "peak_estimate_gb": round((arg_bytes_dev + temp_bytes_dev)
                                      / 2 ** 30, 3),
        },
        "cost_analysis_raw_flops": float(cost.get("flops", 0.0)),
        "hlo": {k: float(v) for k, v in hlo_stats.items()},
        "terms": terms.as_dict(),
    }
    if top_colls is not None:
        rec["top_collectives"] = top_colls
    return rec


ALL_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=3)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--gamma", type=int, default=16)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--loss-seq-chunk", type=int, default=None)
    ap.add_argument("--remat-policy", default=None)
    ap.add_argument("--tag", default="")
    ap.add_argument("--diagnose", action="store_true")
    ap.add_argument("--rules", default=None,
                    help='JSON rules override, e.g. \'{"act_seq": null}\'')
    ap.add_argument("--fsdp", default=None, choices=["on", "off"])
    args = ap.parse_args()

    OUT_DIR.mkdir(parents=True, exist_ok=True)

    if not args.all:
        assert args.arch and args.shape
        name = _cell_name(args.arch, args.shape, args.mesh)
        if args.tag:
            name += f"_{args.tag}"
        out = OUT_DIR / f"{name}.json"
        try:
            rec = run_cell(args.arch, args.shape, args.mesh,
                           gamma=args.gamma, k_branches=args.k,
                           loss_seq_chunk=args.loss_seq_chunk,
                           remat_policy=args.remat_policy, tag=args.tag,
                           diagnose=args.diagnose,
                           rules_override=(json.loads(args.rules)
                                           if args.rules else None),
                           fsdp_override=(None if args.fsdp is None
                                          else args.fsdp == "on"))
        except Exception as e:  # noqa
            rec = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
                   "ok": False, "error": repr(e),
                   "traceback": traceback.format_exc()[-4000:]}
        out.write_text(json.dumps(rec, indent=2))
        print(json.dumps({k: v for k, v in rec.items()
                          if k not in ("traceback",)}, indent=2))
        sys.exit(0 if rec.get("ok") else 1)

    # orchestrate all cells as subprocesses (isolation + parallelism)
    from repro.config.registry import ARCH_IDS
    jobs = []
    for mesh_kind in ("single", "multi"):
        for arch in ARCH_IDS:
            for shape in ALL_SHAPES:
                name = _cell_name(arch, shape, mesh_kind)
                out = OUT_DIR / f"{name}.json"
                if out.exists() and not args.force:
                    try:
                        if json.loads(out.read_text()).get("ok"):
                            continue
                    except Exception:
                        pass
                jobs.append((arch, shape, mesh_kind, out))

    print(f"{len(jobs)} cells to run")
    running = []
    results = []
    while jobs or running:
        while jobs and len(running) < args.jobs:
            arch, shape, mesh_kind, out = jobs.pop(0)
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mesh_kind]
            env = dict(os.environ)
            env["PYTHONPATH"] = str(Path(__file__).resolve().parents[2])
            proc = subprocess.Popen(cmd, env=env,
                                    stdout=subprocess.DEVNULL,
                                    stderr=subprocess.PIPE)
            running.append((proc, arch, shape, mesh_kind, out, time.time()))
            print(f"start {arch} {shape} {mesh_kind}")
        time.sleep(3)
        still = []
        for proc, arch, shape, mesh_kind, out, t0 in running:
            if proc.poll() is None:
                if time.time() - t0 > 3600:
                    proc.kill()
                    print(f"TIMEOUT {arch} {shape} {mesh_kind}")
                else:
                    still.append((proc, arch, shape, mesh_kind, out, t0))
                continue
            ok = proc.returncode == 0
            dt = time.time() - t0
            print(f"done {arch} {shape} {mesh_kind} ok={ok} {dt:.0f}s")
            results.append((arch, shape, mesh_kind, ok))
        running = still
    n_ok = sum(1 for r in results if r[3])
    print(f"\n{n_ok}/{len(results)} newly-run cells ok")


if __name__ == "__main__":
    main()
