"""Serving launcher: ``python -m repro.launch.serve [--mode d2sd] [...]``.

Loads the trained study artifacts (or random weights with --random) and
serves a batch of synthetic requests through the D2SD engine, printing
acceptance + throughput statistics.
"""
from __future__ import annotations

import argparse
import contextlib

import jax
import numpy as np

from repro.config.base import SpecConfig
from repro.core import pipeline as pl
from repro.data.synthetic import SyntheticDataset
from repro.serving.engine import ServingEngine


def _mesh_context(args, ap):
    """``use_sharding`` context for --mesh-data/--mesh-model (nullcontext
    for the default 1x1). The engine captures the context at CONSTRUCTION
    and re-enters it around every device-facing call, so only the
    ``ServingEngine(...)`` call needs to run inside it."""
    if args.mesh_data * args.mesh_model <= 1:
        return contextlib.nullcontext()
    need = args.mesh_data * args.mesh_model
    if jax.device_count() < need:
        ap.error(
            f"--mesh-data x --mesh-model needs {need} devices but only "
            f"{jax.device_count()} are visible; on CPU export XLA_FLAGS="
            f"--xla_force_host_platform_device_count={need}")
    from repro.distributed.sharding import LOGICAL_RULES, use_sharding
    from repro.launch.mesh import make_mesh
    rules = dict(LOGICAL_RULES)
    rules["kv_seq"] = (None if args.kv_seq_axis == "off"
                       else args.kv_seq_axis)
    return use_sharding(make_mesh(args.mesh_data, args.mesh_model), rules)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="d2sd",
                    choices=["d2sd", "dflash", "naive_k", "eagle"])
    ap.add_argument("--gamma", type=int, default=None)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=64)
    ap.add_argument("--task", default="math")
    ap.add_argument("--random", action="store_true",
                    help="random weights (no study artifacts needed)")
    ap.add_argument("--cache-impl", default="dense",
                    choices=["dense", "paged"],
                    help="KV storage: dense per-row buffers or the page-"
                         "pool subsystem (page-granular admission, "
                         "copy-free slot refill)")
    ap.add_argument("--page-size", type=int, default=64)
    ap.add_argument("--prefix-cache", action="store_true",
                    help="radix prefix cache: copy-on-write page sharing "
                         "of committed prefixes across requests (needs "
                         "--cache-impl paged, all-global-attention target)")
    ap.add_argument("--bucket-sizes", default=None,
                    help="comma-separated install-prefill length buckets "
                         "(bounds donated-install recompiles under varying "
                         "prompt lengths), e.g. 32,64,128; 'off' forces "
                         "exact-length installs; default: pow-2 ladder")
    ap.add_argument("--pool-scope", default="engine",
                    choices=["engine", "wave"],
                    help="paged pool lifetime: 'engine' (default) keeps ONE "
                         "page pool for the server's lifetime so cached "
                         "prefixes survive wave turnover (resident "
                         "serving); 'wave' restores the legacy per-wave "
                         "pools")
    ap.add_argument("--pool-pages", type=int, default=None,
                    help="explicit engine-lifetime pool size in pages "
                         "(default: auto-sized from the first wave's "
                         "candidate window by the engine-global rule)")
    ap.add_argument("--pool-headroom", type=float, default=1.0,
                    help="prefix-retention headroom as a fraction of the "
                         "worst-case concurrent live set (prefix cache "
                         "only; default 1.0 = retain up to one live-set's "
                         "worth of cached prefixes)")
    ap.add_argument("--traffic", default=None,
                    choices=["poisson", "bursty"],
                    help="open-loop serving: replay a seeded arrival trace "
                         "(poisson = memoryless, bursty = 2-state MMPP "
                         "clumps) through the async front-end instead of "
                         "submitting a fixed batch; per-request TTFT/TPOT "
                         "SLA percentiles are reported")
    ap.add_argument("--rate", type=float, default=1.0,
                    help="open-loop arrival rate in requests/s "
                         "(with --traffic)")
    ap.add_argument("--duration", type=float, default=30.0,
                    help="open-loop trace length in seconds "
                         "(with --traffic)")
    ap.add_argument("--traffic-seed", type=int, default=0,
                    help="arrival-trace seed (with --traffic); the same "
                         "seed replays the identical trace")
    ap.add_argument("--sync-baseline", action="store_true",
                    help="drive the trace with the synchronous baseline "
                         "(refill only at retire moments) instead of the "
                         "overlapped front-end (with --traffic)")
    ap.add_argument("--virtual-clock", action="store_true",
                    help="replay in deterministic simulated time (1 s per "
                         "decode cycle) instead of wall time "
                         "(with --traffic)")
    ap.add_argument("--mesh-data", type=int, default=1,
                    help="data mesh axis size: ONE resident engine spans "
                         "the (data, model) mesh; batch rows shard over "
                         "this axis when divisible (default 1)")
    ap.add_argument("--mesh-model", type=int, default=1,
                    help="model mesh axis size; with --cache-impl paged "
                         "the page pool's payload bytes shard along it "
                         "(the kv_seq logical axis: page_size must be "
                         "divisible by this) and the cascade verify runs "
                         "under shard_map with an LSE-psum merge — token-"
                         "identical to --mesh-model 1. Needs data*model "
                         "devices; on CPU export XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N "
                         "(default 1 = no mesh)")
    ap.add_argument("--kv-seq-axis", default="model",
                    choices=["model", "data", "off"],
                    help="mesh axis backing the kv_seq logical axis (KV "
                         "page payload placement + decode verify "
                         "sharding); 'off' replicates the KV pool while "
                         "keeping the rest of the mesh rules "
                         "(default: model)")
    args = ap.parse_args()

    if args.random:
        from repro.configs.paper_target import drafter_small, smoke
        from repro.core.drafter import drafter_init
        from repro.models import lm
        tcfg = smoke()
        dcfg = drafter_small(gamma=args.gamma or 8)
        tp = lm.lm_init(jax.random.PRNGKey(0), tcfg)
        d1 = drafter_init(jax.random.PRNGKey(1), dcfg)
        d2 = drafter_init(jax.random.PRNGKey(2), dcfg)
        spec = SpecConfig(gamma=dcfg.gamma, top_k_branches=args.k,
                          mode=args.mode, temperature=args.temperature)
        bundle = pl.SpecBundle(tcfg, dcfg, dcfg, spec, tp, d1, d2)
    else:
        import sys
        from pathlib import Path
        sys.path.insert(0, str(Path(__file__).resolve().parents[3]))
        from benchmarks.common import build_bundle
        bundle = build_bundle(args.mode, gamma=args.gamma, k=args.k,
                              temperature=args.temperature)

    kw = {}
    if args.bucket_sizes is not None:
        if args.bucket_sizes.strip().lower() in ("off", "none"):
            kw["bucket_sizes"] = None
        else:
            buckets = tuple(int(x) for x in args.bucket_sizes.split(",")
                            if x.strip())
            if not buckets or any(b <= 0 for b in buckets):
                ap.error(f"--bucket-sizes must be positive ints, got "
                         f"{args.bucket_sizes!r}")
            kw["bucket_sizes"] = buckets
    if args.traffic is not None:
        from repro.serving.frontend import ReplayDriver
        from repro.serving.metrics import (MetricsRecorder, MonotonicClock,
                                           VirtualClock)
        from repro.serving.traffic import make_trace
        clock = VirtualClock() if args.virtual_clock else MonotonicClock()
        rec = MetricsRecorder(clock)
        trace = make_trace(args.traffic, args.rate, args.duration,
                           seed=args.traffic_seed,
                           max_new=args.max_new,
                           vocab=bundle.target_cfg.vocab_size,
                           tasks=(args.task,))
        pool_pages = args.pool_pages
        if args.cache_impl == "paged" and pool_pages is None:
            # the engine's auto-sizing rule sees only the queue at the
            # first wave — under open-loop traffic that may be a single
            # request. The launcher has the whole trace, so size the
            # pool for the worst-case concurrent set up front.
            g = bundle.spec.gamma
            per = max(-(-(a.prompt_len + a.max_new + 2 * g + 8)
                        // args.page_size) for a in trace)
            pool_pages = 2 * args.requests * per
        with _mesh_context(args, ap):
            eng = ServingEngine(bundle, batch_size=args.requests,
                                cache_impl=args.cache_impl,
                                page_size=args.page_size,
                                prefix_cache=args.prefix_cache,
                                pool_scope=args.pool_scope,
                                pool_pages=pool_pages,
                                pool_headroom=args.pool_headroom,
                                clock=clock, recorder=rec, **kw)
        stats = ReplayDriver(eng, trace,
                             overlap=not args.sync_baseline).run()
        sla = stats["sla"]
        driver = "sync" if args.sync_baseline else "overlapped"
        print(f"mode={args.mode} traffic={args.traffic} rate={args.rate} "
              f"driver={driver} served {len(eng.done)}/{len(trace)} | "
              f"cycles={stats['engine_cycles']} "
              f"alpha={stats.get('alpha', 0):.2f}")
        print(f"  ttft p50={sla['ttft']['p50']:.2f}s "
              f"p99={sla['ttft']['p99']:.2f}s | "
              f"tpot p50={sla['tpot']['p50']:.3f}s "
              f"p99={sla['tpot']['p99']:.3f}s | "
              f"e2e p99={sla['e2e']['p99']:.2f}s | "
              f"queue max={sla['queue_depth']['max']}")
        return

    with _mesh_context(args, ap):
        eng = ServingEngine(bundle, batch_size=args.requests,
                            cache_impl=args.cache_impl,
                            page_size=args.page_size,
                            prefix_cache=args.prefix_cache,
                            pool_scope=args.pool_scope,
                            pool_pages=args.pool_pages,
                            pool_headroom=args.pool_headroom, **kw)
    ds = SyntheticDataset(args.task, 1, 64, seed=11)
    for p in ds.prompts(args.requests, 32, offset=10 ** 7):
        eng.submit(p, max_new=args.max_new)
    stats = eng.run()
    prefix = ""
    if args.prefix_cache:
        prefix = (f" | prefix_hits={stats['prefix_hits']} "
                  f"saved={stats['prefill_tokens_saved']}tok "
                  f"cow={stats['cow_copies']}")
    mesh_note = ""
    if stats.get("kv_shards", 1) > 1:
        mesh_note = (f" | kv_shards={stats['kv_shards']} "
                     f"shard_slots={stats['pool_shard_slots']}")
    print(f"mode={args.mode} served {len(eng.done)} requests | "
          f"alpha={stats.get('alpha', 0):.2f} | "
          f"{stats['tokens_per_s']:.1f} tok/s (CPU) | "
          f"{stats['cycles']} cycles" + prefix + mesh_note)


if __name__ == "__main__":
    main()
