"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs the fault-tolerant training loop on a reduced (smoke) config by
default — full configs are exercised through the dry-run; pass --full only
on real hardware.
"""
from __future__ import annotations

import argparse

import jax

from repro.config.base import OptimizerConfig, TrainConfig
from repro.config.registry import all_archs, get_config
from repro.data.synthetic import SyntheticDataset
from repro.launch.steps import optimizer_for
from repro.models import api
from repro.optim import optimizers as opt_lib
from repro.training.trainer import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b", choices=all_archs())
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=not args.full)
    hp = OptimizerConfig(name=optimizer_for(cfg).name, lr=args.lr,
                         total_steps=args.steps,
                         warmup_steps=max(args.steps // 10, 1))
    tc = TrainConfig(batch_size=args.batch, seq_len=args.seq, optimizer=hp,
                     checkpoint_every=max(args.steps // 4, 10),
                     checkpoint_dir=args.ckpt_dir,
                     log_every=max(args.steps // 20, 1))
    print(f"training {cfg.name}: {cfg.param_count():.3g} params, "
          f"opt={hp.name}")

    params = api.init_model(jax.random.PRNGKey(0), cfg)
    opt_init, opt_update = opt_lib.make_optimizer(hp)
    opt_state = opt_init(params)
    ds = SyntheticDataset("mixture", args.batch, args.seq, seed=0)

    @jax.jit
    def step_fn(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: api.train_loss(p, batch, cfg))(params)
        p2, o2, m = opt_update(grads, opt_state, params)
        return p2, o2, {"loss": loss, **m}

    state = {"params": params, "opt_state": opt_state, "step": 0}
    if args.resume:
        from repro.checkpoint.checkpointer import Checkpointer
        ck = Checkpointer(args.ckpt_dir)
        if ck.latest_step() is not None:
            restored, extra = ck.restore(
                {"params": params, "opt_state": opt_state})
            state.update(params=restored["params"],
                         opt_state=restored["opt_state"],
                         step=int(extra["step"]))
            ds.load_state_dict(extra["data"])
            print(f"resumed from step {state['step']}")
    out = train(step_fn, state, ds, tc)
    print(f"done: final loss {out['metrics'][-1]['loss']:.4f}, "
          f"restarts={out['restarts']}, stragglers={out['stragglers']}")


if __name__ == "__main__":
    main()
