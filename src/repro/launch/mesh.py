"""Production mesh factory (assignment: MULTI-POD DRY-RUN item 1).

A FUNCTION, not a module constant — importing this module never touches jax
device state. Mesh construction goes through
:mod:`repro.distributed.compat` so the Auto ``axis_types`` kwarg is only
passed on jax versions that understand it.
"""
from __future__ import annotations

from repro.distributed import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(data: int, model: int, pod: int = 1):
    """Arbitrary mesh for tests / small-scale runs."""
    if pod > 1:
        return compat.make_mesh((pod, data, model), ("pod", "data", "model"))
    return compat.make_mesh((data, model), ("data", "model"))
