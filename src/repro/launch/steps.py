"""Step functions + abstract input specs for every (arch x shape) cell.

Used by the multi-pod dry-run, the roofline analysis, and the launchers.
``input_specs`` returns ShapeDtypeStruct stand-ins — weak-type-correct,
shardable, no device allocation. The full-size configs are only ever
exercised through these abstract paths.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig, ShapeSpec, SpecConfig, \
    OptimizerConfig, shape_by_name
from repro.config.registry import get_config
from repro.core import pipeline as pl
from repro.core.drafter import DrafterConfig, drafter_init, init_feat_cache
from repro.models import api, encdec, lm
from repro.optim import optimizers as opt_lib

GAMMA_PROD = 16
K_PROD = 4


def production_drafter(tcfg: ModelConfig, gamma: int = GAMMA_PROD,
                       causal: bool = False) -> DrafterConfig:
    from repro.models.lm import feature_dim
    d = max(512, (tcfg.d_model // 4) // 128 * 128)
    heads = max(4, d // 128)
    kv = 2 if heads % 2 == 0 else 1          # must divide heads
    return DrafterConfig(
        d_model=d, num_layers=2, num_heads=heads,
        num_kv_heads=kv, d_ff=3 * d,
        vocab_size=tcfg.vocab_size, target_feature_dim=feature_dim(tcfg),
        gamma=gamma, causal=causal)


def optimizer_for(cfg: ModelConfig) -> OptimizerConfig:
    # factored moments for the giant MoEs; int8 moments for mid-size; plain
    # AdamW for small models
    n = cfg.param_count()
    if n > 1e11:
        return OptimizerConfig(name="adafactor")
    if n > 3e9:
        return OptimizerConfig(name="adamw8bit")
    return OptimizerConfig(name="adamw")


def _cap_for(seq_len: int) -> int:
    return seq_len + 1024


# ---------------------------------------------------------------- specs ----
def abstract_params(cfg: ModelConfig, dtype=None):
    shapes = jax.eval_shape(lambda k: api.init_model(k, cfg),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    if dtype is not None:
        shapes = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, dtype if jnp.issubdtype(s.dtype, jnp.floating)
                else s.dtype), shapes)
    return shapes


def abstract_tree(fn, *args):
    return jax.eval_shape(fn, *args)


def _sds_tree(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def make_bundle_abstract(cfg: ModelConfig, spec: SpecConfig,
                         serve_dtype=jnp.bfloat16):
    d1_cfg = production_drafter(cfg, spec.gamma)
    d2_cfg = production_drafter(cfg, spec.gamma)
    tp = abstract_params(cfg, serve_dtype)
    dp1 = jax.eval_shape(lambda k: drafter_init(k, d1_cfg),
                         jax.ShapeDtypeStruct((2,), jnp.uint32))
    dp2 = jax.eval_shape(lambda k: drafter_init(k, d2_cfg),
                         jax.ShapeDtypeStruct((2,), jnp.uint32))
    if serve_dtype is not None:
        cast = lambda s: jax.ShapeDtypeStruct(
            s.shape, serve_dtype if jnp.issubdtype(s.dtype, jnp.floating)
            else s.dtype)
        dp1 = jax.tree.map(cast, dp1)
        dp2 = jax.tree.map(cast, dp2)
    return pl.SpecBundle(cfg, d1_cfg, d2_cfg, spec, tp, dp1, dp2)


def ctx_len_for(cfg: ModelConfig) -> int:
    if cfg.is_encoder_decoder:
        return cfg.enc_max_len
    if cfg.cross_attn_every:
        return max(cfg.num_vision_tokens, 1)
    return 0


def engine_state_abstract(bundle, batch: int, max_len: int):
    return jax.eval_shape(
        lambda: pl.engine_init(bundle, batch, max_len,
                               ctx_len=ctx_len_for(bundle.target_cfg)))


# ----------------------------------------------------------- step makers ---
def make_train_step(cfg: ModelConfig, loss_seq_chunk: Optional[int] = None):
    hp = optimizer_for(cfg)
    opt_init, opt_update = opt_lib.make_optimizer(hp)

    def train_step(params, opt_state, batch):
        from repro.distributed.sharding import constrain_params
        params = constrain_params(params)
        loss, grads = jax.value_and_grad(
            lambda p: api.train_loss(p, batch, cfg,
                                     loss_seq_chunk=loss_seq_chunk))(params)
        new_p, new_o, metrics = opt_update(grads, opt_state, params)
        return new_p, new_o, {"loss": loss, **metrics}

    return train_step, opt_init


def make_prefill_step(cfg: ModelConfig):
    if cfg.is_encoder_decoder:
        def step(enc_params, bundle, est, prompts, audio_feats):
            ctx = encdec.encode(enc_params, audio_feats, cfg)
            return pl.prefill(bundle, est, prompts, ctx=ctx)
        return step
    if cfg.cross_attn_every:
        def step(bundle, est, prompts, image_embeds):
            return pl.prefill(bundle, est, prompts, ctx=image_embeds)
        return step

    def step(bundle, est, prompts):
        return pl.prefill(bundle, est, prompts)
    return step


def make_serve_step():
    def serve_step(bundle, est, key):
        return pl.decode_cycle(bundle, est, key, collect_stats=False)
    return serve_step


# ----------------------------------------------------------- cell specs ----
@dataclasses.dataclass
class CellSpec:
    """Everything needed to lower one (arch x shape) cell."""
    fn: Any                      # the step callable
    args: Tuple[Any, ...]        # abstract arguments (SDS pytrees)
    rules: Dict[str, Any]        # logical sharding rules profile
    fsdp: bool
    kind: str


def build_cell(arch: str, shape_name: str,
               gamma: int = GAMMA_PROD, k_branches: int = K_PROD,
               loss_seq_chunk: Optional[int] = None,
               remat_policy: Optional[str] = None) -> Optional[CellSpec]:
    """Returns None when the cell is skipped (long_500k on quadratic archs).
    """
    from repro.distributed.sharding import LOGICAL_RULES
    cfg = get_config(arch)
    if remat_policy is not None:
        cfg = dataclasses.replace(cfg, remat_policy=remat_policy)
    shape = shape_by_name(shape_name)
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return None

    rules = dict(LOGICAL_RULES)
    if shape.kind == "train":
        rules["act_seq"] = "model"
        rules["kv_seq"] = None
        spec_c = None
        step, opt_init = make_train_step(cfg, loss_seq_chunk)
        params = abstract_params(cfg)
        opt_state = jax.eval_shape(opt_init, params)
        batch = api.batch_specs(cfg, shape.global_batch, shape.seq_len)
        return CellSpec(step, (params, opt_state, batch), rules, True,
                        "train")

    spec_c = SpecConfig(gamma=gamma, top_k_branches=k_branches)
    bundle = make_bundle_abstract(cfg, spec_c)
    cap = _cap_for(shape.seq_len)
    # serving: TP-sharded weights replicated across data, except the giant
    # MoEs whose weights don't fit a single model-axis shard
    serve_fsdp = cfg.param_count() > 1e11

    if shape.kind == "prefill":
        rules["act_seq"] = "model"
        rules["kv_seq"] = "model"
        est = engine_state_abstract(bundle, shape.global_batch, cap)
        prompts = jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len),
                                       jnp.int32)
        step = make_prefill_step(cfg)
        if cfg.is_encoder_decoder:
            enc = abstract_params(cfg, jnp.bfloat16)["encoder"]
            audio = jax.ShapeDtypeStruct(
                (shape.global_batch, cfg.enc_max_len, cfg.d_model),
                jnp.bfloat16)
            bundle_dec = dataclasses.replace(
                bundle, target_params=bundle.target_params["decoder"])
            args = (enc, bundle_dec, est, prompts, audio)
        elif cfg.cross_attn_every:
            img = jax.ShapeDtypeStruct(
                (shape.global_batch, max(cfg.num_vision_tokens, 1),
                 cfg.d_model), jnp.bfloat16)
            args = (bundle, est, prompts, img)
        else:
            args = (bundle, est, prompts)
        return CellSpec(step, args, rules, serve_fsdp, "prefill")

    # decode
    rules["act_seq"] = None
    rules["kv_seq"] = "model"
    est = engine_state_abstract(bundle, shape.global_batch, cap)
    if cfg.is_encoder_decoder:
        bundle = dataclasses.replace(
            bundle, target_params=bundle.target_params["decoder"])
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return CellSpec(make_serve_step(), (bundle, est, key), rules,
                    serve_fsdp, "decode")
