"""Block-diffusion drafters (DFlash first draft + VP-Drafter second draft).

Architecture (paper §2 "DFlash", §3.4): a lightweight transformer whose input
is a gamma-token block ([anchor, MASK, ..., MASK] for DFlash; [anchor,
prefix..., MASK...] for the VP-Drafter). Every layer's attention consumes

    K/V = [ W_k/v^l( proj(target multi-layer features) ) ;  W_k/v^l(block) ]

i.e. target hidden features are FC-projected once and *injected into the key
and value projections of every drafter layer* (the "KV injection"); mask
tokens attend bidirectionally within the block and to all injected context.

The projected per-layer context K/V are cached across decoding cycles (the
"feature cache", the drafter analogue of a KV cache) — one entry per
committed target position.

The same module runs the EAGLE-style autoregressive baseline by switching
``causal=True`` (chain drafting, one token per inner step).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import kvcache as kvc
from repro.models import param as pm
from repro.models.attention import attend
from repro.models.layers import apply_rope, dense, rmsnorm, rmsnorm_init
from repro.models.mlp import mlp, mlp_init
from repro.distributed.sharding import constrain


@dataclasses.dataclass(frozen=True)
class DrafterConfig:
    d_model: int = 256
    num_layers: int = 2
    num_heads: int = 4
    num_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 512
    target_feature_dim: int = 768      # feature_layers * target d_model
    gamma: int = 16
    rope_theta: float = 10000.0
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    causal: bool = False               # True => EAGLE-style AR drafter
    # Feature-cache read path, mirroring ModelConfig.attn_impl (jit-static
    # via SpecBundle aux_data): "pallas" reads paged feature pools through
    # the cascade kernel per layer instead of one dense pool_view gather.
    # kv_seq-sharded paged pools go through the shard_map read hook
    # (spdecode.sharded_paged_cache_attend) with read_impl=attn_impl —
    # each shard reads only its local pool slice either way, so sharded
    # engines draft without the per-cycle dense GSPMD gather. Dense
    # caches keep the plain gather/chunked path.
    attn_impl: str = "gather"

    def __post_init__(self):
        assert self.attn_impl in ("gather", "pallas"), (
            f"attn_impl={self.attn_impl!r} not in ('gather', 'pallas')")

    @property
    def head_dim(self) -> int:
        return self.d_model // self.num_heads

    @property
    def mask_token(self) -> int:
        return self.vocab_size         # embedding table has vocab+1 rows


def drafter_init(key, dcfg: DrafterConfig):
    ks = pm.split(key, 4 + dcfg.num_layers)
    hq, hkv, dh = dcfg.num_heads, dcfg.num_kv_heads, dcfg.head_dim
    d = dcfg.d_model
    p = {
        "tok": {"embedding": pm.trunc_normal(
            ks[0], (dcfg.vocab_size + 1, d), stddev=0.02)},
        "feat_proj": pm.dense_init(ks[1], dcfg.target_feature_dim, d),
        "ln_f": rmsnorm_init(d),
        "head": pm.dense_init(ks[2], d, dcfg.vocab_size, scale=0.02),
    }
    for i in range(dcfg.num_layers):
        kk = pm.split(ks[4 + i], 6)
        p[f"layer{i}"] = {
            "ln1": rmsnorm_init(d),
            "wq": pm.dense_init(kk[0], d, hq * dh),
            "wk": pm.dense_init(kk[1], d, hkv * dh),
            "wv": pm.dense_init(kk[2], d, hkv * dh),
            "wo": pm.dense_init(kk[3], hq * dh, d, scale=(hq * dh) ** -0.5),
            "ln2": rmsnorm_init(d),
            "mlp": mlp_init(kk[4], d, dcfg.d_ff, gated=True),
        }
    return p


# ----------------------------------------------------------- feature cache --
def init_feat_cache(dcfg: DrafterConfig, batch: int, max_len: int,
                    dtype=jnp.bfloat16, cache_impl: str = "dense",
                    page_size: int = 64, pool_pages=None, page_table=None,
                    ext_pool=None):
    """Dense: k/v [L, B, S_max, Hkv, Dh]. Paged: stacked page pools
    [L, P, page, Hkv, Dh] plus the wave's shared page table ``pt``
    [B, max_pages] (same page-id space as the target KV pools, so one
    host allocation covers every cache of a row). ``ext_pool`` (paged):
    retained ``(k, v)`` device buffers from a previous wave adopted in
    place of fresh zeroed pools (borrowed-pool wave turnover)."""
    l, hkv, dh = dcfg.num_layers, dcfg.num_kv_heads, dcfg.head_dim
    if cache_impl == "paged":
        pool_pages, page_table = kvc.default_page_layout(
            batch, max_len, page_size, pool_pages, page_table)
        if ext_pool is not None:
            k, v = ext_pool
            assert k.shape == (l, pool_pages, page_size, hkv, dh) \
                and k.dtype == dtype, ("retained feature-pool geometry "
                                       "mismatch", k.shape)
        else:
            k = kvc.init_pool(pool_pages, page_size, hkv, dh, dtype,
                              lead=(l,))
            v = kvc.init_pool(pool_pages, page_size, hkv, dh, dtype,
                              lead=(l,))
        return {
            "k": k,
            "v": v,
            # copy=True: every paged cache holds its own table buffer so
            # the whole state can be donated (no twice-donated aliases)
            "pt": jnp.array(page_table, jnp.int32, copy=True),
            "length": jnp.zeros((batch,), jnp.int32),
        }
    return {
        "k": jnp.zeros((l, batch, max_len, hkv, dh), dtype),
        "v": jnp.zeros((l, batch, max_len, hkv, dh), dtype),
        "length": jnp.zeros((batch,), jnp.int32),
    }


def project_features(p, dcfg: DrafterConfig, target_features, positions):
    """target_features: [B,T,Fd]; positions: [B,T] absolute.

    Returns per-layer context (k, v): ([L,B,T,Hkv,Dh], [L,B,T,Hkv,Dh]).
    """
    b, t, _ = target_features.shape
    hkv, dh = dcfg.num_kv_heads, dcfg.head_dim
    f = dense(p["feat_proj"], target_features.astype(jnp.dtype(dcfg.dtype)))
    ks, vs = [], []
    for i in range(dcfg.num_layers):
        lp = p[f"layer{i}"]
        k = dense(lp["wk"], f).reshape(b, t, hkv, dh)
        v = dense(lp["wv"], f).reshape(b, t, hkv, dh)
        k = apply_rope(k, positions, dcfg.rope_theta)
        ks.append(k)
        vs.append(v)
    return jnp.stack(ks), jnp.stack(vs)


def extend_feat_cache(p, dcfg, cache, target_features, positions, n_new):
    """Append features of newly committed tokens (per-example ragged).

    target_features: [B,P,Fd] gathered along the accepted path (padded);
    positions: [B,P] their absolute positions; n_new: [B] valid counts.
    """
    k_new, v_new = project_features(p, dcfg, target_features, positions)
    b, pl = positions.shape
    valid = jnp.arange(pl)[None, :] < n_new[:, None]
    out = dict(cache)
    if kvc.is_paged(cache):
        out["k"] = kvc.pool_scatter(cache["k"], cache["pt"], k_new,
                                    positions, valid=valid)
        out["v"] = kvc.pool_scatter(cache["v"], cache["pt"], v_new,
                                    positions, valid=valid)
    else:
        cap = cache["k"].shape[2]
        wpos = jnp.where(valid, positions, cap + 1)
        bidx = jnp.broadcast_to(jnp.arange(b)[:, None], (b, pl))
        out["k"] = cache["k"].at[:, bidx, wpos].set(
            k_new.astype(cache["k"].dtype), mode="drop")
        out["v"] = cache["v"].at[:, bidx, wpos].set(
            v_new.astype(cache["v"].dtype), mode="drop")
    out["length"] = cache["length"] + n_new
    return out


# ----------------------------------------------------------------- forward --
def drafter_forward(p, dcfg: DrafterConfig, block_tokens, feat_cache,
                    positions=None, block_mask=None, attn_impl: str = "auto",
                    kv_chunk: int = 1024):
    """block_tokens: [B,T] (mask token = dcfg.mask_token).

    positions: [B,T] absolute positions of block slots (default: feat_len+i).
    block_mask: optional [T,T] or [B,T,T] intra-block mask; default
        bidirectional (diffusion) or causal when dcfg.causal.
    Returns logits [B,T,V].
    """
    b, t = block_tokens.shape
    dtype = jnp.dtype(dcfg.dtype)
    hq, hkv, dh = dcfg.num_heads, dcfg.num_kv_heads, dcfg.head_dim
    feat_len = feat_cache["length"]
    if positions is None:
        positions = feat_len[:, None] + jnp.arange(t)[None, :]
    x = p["tok"]["embedding"].astype(dtype)[block_tokens]
    x = constrain(x, ("batch", None, "embed"))

    if block_mask is None and dcfg.causal:
        block_mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    elif block_mask is None:
        block_mask = jnp.ones((t, t), dtype=bool)

    from repro.distributed import spdecode as _sp
    paged = kvc.is_paged(feat_cache)
    axis = _sp.kv_seq_axis()
    # Kernelized paged read (dcfg.attn_impl, jit-static): every layer calls
    # the cascade kernel on its pool slice + the shared page table — no
    # dense-sized pool_view gather per cycle. Block slots sit at positions
    # >= feat_len, so the kernel's causal kpos<=q_abs clamp is subsumed by
    # its kpos<feat_len mask and both paths attend identically.
    use_pallas = (paged and dcfg.attn_impl == "pallas" and axis is None)
    # kv_seq-sharded feature pools: the same shard_map hook the verify
    # read uses (per-shard local pool reads — gather of the LOCAL slice or
    # the pos_stride/pos_offset kernel — merged by the fp32 LSE psum), so
    # sharded engines draft without a per-cycle dense GSPMD gather.
    use_sharded = (paged and axis is not None
                   and feat_cache["k"].shape[-3] % _sp.kv_seq_shards() == 0)
    if paged and not (use_pallas or use_sharded):
        # logical per-row view gathered once for all drafter layers;
        # garbage beyond feat_len is masked below exactly like the dense
        # cache's zero padding, so both layouts attend identically
        ctx_k = kvc.pool_view(feat_cache["k"], feat_cache["pt"])
        ctx_v = kvc.pool_view(feat_cache["v"], feat_cache["pt"])
    elif paged:
        ctx_k, ctx_v = feat_cache["k"], feat_cache["v"]   # [L,P,page,Hkv,Dh]
    else:
        ctx_k, ctx_v = feat_cache["k"], feat_cache["v"]
    cap = (kvc.logical_len(feat_cache) if (use_pallas or use_sharded)
           else ctx_k.shape[2])
    tq = t
    if block_mask.ndim == 2:
        blk = jnp.broadcast_to(block_mask[None], (b, tq, t))
    else:
        blk = block_mask
    full_mask = None
    if not (use_pallas or use_sharded):
        # context visibility: feature entries < feat_len (per-example)
        ctx_ok = (jnp.arange(cap)[None, None, :]
                  < feat_len[:, None, None])                 # [B,1,cap]
        ctx_ok = jnp.broadcast_to(ctx_ok, (b, tq, cap))
        full_mask = jnp.concatenate([ctx_ok, blk], axis=-1)

    spdecode = _sp
    use_sp = False
    if axis is not None and not paged:
        from repro.distributed.sharding import active_mesh
        n_shards = dict(zip(active_mesh().axis_names,
                            active_mesh().devices.shape))[axis]
        use_sp = cap % n_shards == 0 and cap // n_shards >= 128

    for i in range(dcfg.num_layers):
        lp = p[f"layer{i}"]
        h = rmsnorm(lp["ln1"], x, dcfg.norm_eps)
        q = dense(lp["wq"], h).reshape(b, t, hq, dh)
        k = dense(lp["wk"], h).reshape(b, t, hkv, dh)
        v = dense(lp["wv"], h).reshape(b, t, hkv, dh)
        q = apply_rope(q, positions, dcfg.rope_theta)
        k = apply_rope(k, positions, dcfg.rope_theta)
        if use_pallas:
            from repro.kernels import ops as kops
            y = kops.cascade_attention_paged(
                q, ctx_k[i].astype(k.dtype), ctx_v[i].astype(v.dtype),
                feat_cache["pt"], k, v, cache_len=feat_len,
                q_abs=positions, tree_mask=blk, layout="BTHD")
        elif use_sharded:
            y = spdecode.sharded_paged_cache_attend(
                q, ctx_k[i].astype(k.dtype), ctx_v[i].astype(v.dtype),
                feat_cache["pt"], k, v, cache_len=feat_len,
                q_abs=positions, attn_softcap=None, blk_mask=blk,
                page_size=feat_cache["k"].shape[-3], kv_chunk=kv_chunk,
                read_impl=dcfg.attn_impl)
        elif use_sp:
            y = spdecode.sharded_cache_attend(
                q, ctx_k[i].astype(k.dtype),
                ctx_v[i].astype(v.dtype), k, v,
                cache_len=feat_len, q_abs=positions, window=None,
                attn_softcap=None, blk_mask=blk, rolling=False,
                kv_chunk=kv_chunk)
        else:
            kk = jnp.concatenate([ctx_k[i].astype(k.dtype), k], axis=1)
            vv = jnp.concatenate([ctx_v[i].astype(v.dtype), v], axis=1)
            y = attend(q, kk, vv, causal=False, extra_mask=full_mask,
                       impl=attn_impl, kv_chunk=kv_chunk)
        x = x + dense(lp["wo"], y.reshape(b, t, hq * dh))
        h = rmsnorm(lp["ln2"], x, dcfg.norm_eps)
        x = x + mlp(lp["mlp"], h)
    x = rmsnorm(p["ln_f"], x, dcfg.norm_eps)
    return dense(p["head"], x)


def dflash_block(anchor, gamma: int, mask_token: int):
    """[B] -> [B, gamma]: [anchor, MASK, ..., MASK]."""
    b = anchor.shape[0]
    blk = jnp.full((b, gamma), mask_token, jnp.int32)
    return blk.at[:, 0].set(anchor)


def vp_blocks(anchor, trunk_tokens, fork_idx, mask_token: int):
    """Second-draft inputs (paper step iii).

    anchor: [B]; trunk_tokens: [B, gamma-1] (or per-branch [B, K, gamma-1]
    for third-level drafts); fork_idx: [B, K].
    Returns [B, K, gamma]: branch b keeps anchor + first fork_b prefix tokens
    visible and re-masks the rest.
    """
    k = fork_idx.shape[1]
    g1 = trunk_tokens.shape[-1]
    slots = jnp.arange(g1 + 1)[None, None, :]             # [1,1,gamma]
    if trunk_tokens.ndim == 2:
        trunk_tokens = jnp.broadcast_to(
            trunk_tokens[:, None, :], (trunk_tokens.shape[0], k, g1))
    b = trunk_tokens.shape[0]
    full = jnp.concatenate(
        [jnp.broadcast_to(anchor[:, None, None], (b, k, 1)), trunk_tokens],
        axis=2)                                            # [B,K,gamma]
    visible = slots <= fork_idx[:, :, None]               # anchor + prefix
    return jnp.where(visible, full, mask_token).astype(jnp.int32)


def ar_chain_draft(p, dcfg: DrafterConfig, anchor, feat_cache, steps: int,
                   temperature: float = 0.0, key=None):
    """EAGLE-style baseline: draft ``steps`` tokens autoregressively.

    Runs ``steps`` causal forwards over the growing block (small gamma, so
    recompute beats cache bookkeeping). Returns (tokens [B,steps],
    logits [B,steps,V]).
    """
    b = anchor.shape[0]
    g = steps + 1
    blk = jnp.full((b, g), 0, jnp.int32).at[:, 0].set(anchor)

    def step(carry, i):
        blk, key = carry
        logits = drafter_forward(p, dcfg, blk, feat_cache,
                                 block_mask=jnp.tril(jnp.ones((g, g), bool)))
        li = logits[jnp.arange(b), i]                     # [B,V]
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, li / temperature)
        else:
            tok = jnp.argmax(li, axis=-1)
        blk = blk.at[:, i + 1].set(tok.astype(jnp.int32))
        return (blk, key), li

    key = key if key is not None else jax.random.PRNGKey(0)
    (blk, _), logit_seq = jax.lax.scan(step, (blk, key), jnp.arange(steps))
    logits = jnp.moveaxis(logit_seq, 0, 1)                # [B,steps,V]
    return blk[:, 1:], logits
