"""D2SD decode engine: strategy/backend composition + generation loops.

Architecture (post API-redesign)
--------------------------------
One decode cycle is the composition of three pluggable pieces over a typed
:class:`~repro.core.state.EngineState` pytree:

1. **DraftStrategy** (``core/strategies.py``) — registry-dispatched on
   ``SpecConfig.mode``; turns ``(bundle, state, key)`` into a candidate
   :class:`~repro.core.tree.Tree` plus per-node proposal distributions.
   The paper modes (d2sd / dflash / naive_k / dflash_second / eagle,
   §3.3 + Tables 5-7) are the built-in registrations; a new drafter
   variant registers a class and needs no engine change.
2. **VerifierBackend** (``core/verify.py``) — selected from target
   ``ModelConfig`` capabilities: cascade tree-attention verify for
   pure-attention targets, branch-batched state-replay verify for
   SSM/hybrid targets (DESIGN §5.1).
3. **Commit** — :func:`decode_cycle` itself only wires draft -> verify ->
   feature-cache extension and emits the accepted tokens.

Generation loops: :func:`generate` is the legacy host loop (numpy sync per
cycle, per-example ragged copy-out, calibration stats);
:func:`generate_ondevice` runs the *entire* loop inside a single
``jax.lax.while_loop`` with a padded on-device output buffer — no host
round-trip per cycle — and is the serving fast path. Both produce
token-identical output for the same keys.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig, SpecConfig
from repro.core import strategies as strat_lib
from repro.core import verify as verify_lib
from repro.core.state import EngineState, engine_init, prefill  # noqa: F401
from repro.core.verify import uses_tree_attention  # noqa: F401 (back-compat)
from repro.core import drafter as dr


@dataclasses.dataclass(frozen=True)
class SpecBundle:
    target_cfg: ModelConfig
    d1_cfg: dr.DrafterConfig
    d2_cfg: dr.DrafterConfig
    spec: SpecConfig
    target_params: Any
    d1_params: Any
    d2_params: Any


jax.tree_util.register_pytree_node(
    SpecBundle,
    lambda s: ((s.target_params, s.d1_params, s.d2_params),
               (s.target_cfg, s.d1_cfg, s.d2_cfg, s.spec)),
    lambda aux, ch: SpecBundle(aux[0], aux[1], aux[2], aux[3], *ch),
)


def with_attn_impl(bundle: SpecBundle, impl: str) -> SpecBundle:
    """Bundle with the KV/feature-cache read path set to ``impl``
    ("gather" | "pallas") on the target AND both drafters.

    Configs live in SpecBundle aux_data, so the returned bundle is a
    distinct jit-cache key — every decode trace retraces with the selected
    read path (``ModelConfig.attn_impl`` / ``DrafterConfig.attn_impl``).
    Token-identical by construction; used by benches/tests for A/B.
    """
    return SpecBundle(
        dataclasses.replace(bundle.target_cfg, attn_impl=impl),
        dataclasses.replace(bundle.d1_cfg, attn_impl=impl),
        dataclasses.replace(bundle.d2_cfg, attn_impl=impl),
        bundle.spec, bundle.target_params, bundle.d1_params,
        bundle.d2_params)


# -------------------------------------------------------------- the cycle --
def decode_cycle(bundle: SpecBundle, state: EngineState, key,
                 collect_stats: bool = True, shard_tag=None):
    """One full speculative decoding cycle.

    ``shard_tag`` (static, ``sharding.mesh_tag()``): cache-splitter only —
    under an active mesh the trace differs (sharding constraints + the
    shard_map cascade-verify hook in ``models/blocks.py``), which jit's
    aval-keyed cache cannot see; the serving engine passes its captured
    tag so sharded and single-device engines coexist in one process.

    Rows with ``state.active == False`` are masked end to end: their draft
    tree degenerates to the root, the verifier commits zero tokens (no KV
    or feature-cache writes, length frozen), the anchor is carried over
    unchanged, and ``n_out`` is 0. The batched draft/verify FLOPs still
    run for masked rows (static shapes) — the win is that a finished
    request parks in its slot with zero state mutation, so the slot can
    be re-prefilled in place and stats stay clean.

    Returns (state', out) with out = dict(tokens [B, D+1], n_out [B],
    n_acc [B], plus calibration stats when collect_stats).
    """
    strategy = strat_lib.get_strategy(bundle.spec.mode)
    backend = verify_lib.select_backend(bundle.target_cfg)
    k_draft, k_verify = jax.random.split(key)
    active = state.active

    draft = strategy.draft(bundle, state, k_draft)
    # inactive rows (finished requests / idle serving slots) degenerate to
    # a root-only tree: nothing is accepted, nothing is committed below
    draft = strat_lib.mask_inactive(draft, active)
    vo = backend.verify(bundle, state, draft.tree, draft.dprobs,
                        draft.max_children, k_verify)
    res = vo.res
    tree = draft.tree

    # ---------------- feature-cache extension ----------------
    n_acc = jnp.where(active, res["n_acc"], 0)
    n_commit = jnp.where(active, res["n_acc"] + 1, 0)
    fpos = (state.length[:, None]
            + jnp.arange(res["path"].shape[1])[None, :])
    state2 = state.replace(
        target=vo.target,
        d1_feat=dr.extend_feat_cache(
            bundle.d1_params, bundle.d1_cfg, state.d1_feat, vo.path_feats,
            fpos, n_commit),
        d2_feat=dr.extend_feat_cache(
            bundle.d2_params, bundle.d2_cfg, state.d2_feat, vo.path_feats,
            fpos, n_commit),
        anchor=jnp.where(active, res["bonus"],
                         state.anchor).astype(jnp.int32))

    # ---------------- outputs ----------------
    path_tokens = jnp.take_along_axis(tree.tokens, res["path"], axis=1)
    d_idx = jnp.arange(res["path"].shape[1])[None, :]
    out_tok = jnp.where(d_idx < n_acc[:, None],
                        jnp.roll(path_tokens, -1, axis=1), 0)
    # slot d: accepted draft d+1 => path_tokens[d+1]; slot n_acc: bonus
    out_tok = jnp.where((d_idx == n_acc[:, None]) & active[:, None],
                        res["bonus"][:, None], out_tok)
    out = {"tokens": out_tok, "n_out": n_commit, "n_acc": n_acc}
    if collect_stats and draft.conf is not None:
        # calibration: trunk confidences vs trunk-node acceptance (greedy ok)
        g = bundle.spec.gamma
        trunk_ok = (res["ok"][:, 1:g] if res.get("ok") is not None else None)
        out["conf"] = draft.conf
        out["trunk_ok"] = trunk_ok
    return state2, out


# -------------------------------------------------------------- generate ---
# Module-level jit: SpecBundle's aux (configs) is hashable, so repeated
# generate() calls with the same shapes hit the trace cache instead of
# re-tracing a fresh closure per call.
_cycle_jit = functools.partial(
    jax.jit, static_argnames=("collect_stats", "shard_tag"))(decode_cycle)


def generate(bundle: SpecBundle, prompts, max_new: int, key=None, ctx=None,
             max_len: Optional[int] = None, collect_stats: bool = True,
             early_exit: bool = True, cache_impl: str = "dense",
             page_size: int = 64):
    """Generate up to ``max_new`` tokens for prompts [B, P] (host loop over
    jitted cycles). Returns dict(tokens [B, max_new], n_cycles, alpha, stats).

    early_exit: mask rows that already reached ``max_new`` so they stop
    committing tokens / mutating caches (per-example ``EngineState.active``);
    token output is identical either way — only finished rows' wasted
    commits (and their dilution of ``alpha``) change.

    cache_impl: "dense" | "paged" KV storage (identity page layout here —
    the serving engine owns real page allocation). Token output is
    identical across impls: the paged logical view matches the dense cache
    at every committed position and garbage beyond it is masked the same.

    Back-compat wrapper: use :func:`generate_ondevice` when you do not need
    per-cycle calibration stats — it avoids the per-cycle host sync.
    """
    import numpy as np

    b, p = prompts.shape
    g = bundle.spec.gamma
    key = key if key is not None else jax.random.PRNGKey(0)
    max_len = max_len or (p + max_new + 2 * g + 8)
    state = engine_init(bundle, b, max_len, cache_impl=cache_impl,
                        page_size=page_size)
    kpre, key = jax.random.split(key)
    state = prefill(bundle, state, prompts, key=kpre, ctx=ctx,
                    temperature=bundle.spec.temperature)
    first = np.asarray(state.anchor)

    from repro.distributed import sharding as sh_lib

    def cycle(s, k):
        return _cycle_jit(bundle, s, k, collect_stats=collect_stats,
                          shard_tag=sh_lib.mesh_tag())

    out_buf = np.zeros((b, max_new + g + 1), np.int32)
    out_buf[:, 0] = first
    filled = np.ones((b,), np.int64)
    n_cycles = 0
    act_cycles = 0
    stats = {"n_acc": [], "n_out": [], "conf": [], "trunk_ok": []}
    while filled.min() < max_new:
        below = filled < max_new
        act_cycles += int(below.sum()) if early_exit else b
        if early_exit:
            state = state.replace(active=jnp.asarray(below))
        key, sub = jax.random.split(key)
        state, out = cycle(state, sub)
        toks = np.asarray(out["tokens"])
        n_out = np.asarray(out["n_out"])
        for i in range(b):
            m = min(int(n_out[i]), out_buf.shape[1] - int(filled[i]))
            if m > 0:
                out_buf[i, filled[i]: filled[i] + m] = toks[i, :m]
        filled = np.minimum(filled + n_out, out_buf.shape[1])
        n_cycles += 1
        stats["n_acc"].append(np.asarray(out["n_acc"]))
        stats["n_out"].append(n_out)
        if collect_stats and "conf" in out:
            # calibration rows only for rows that were still generating:
            # a masked row's tree is invalidated, so its trunk_ok would be
            # forced-False against a real conf and skew the curve
            conf = np.asarray(out["conf"])
            stats["conf"].append(conf[below] if early_exit else conf)
            if out["trunk_ok"] is not None:
                tok = np.asarray(out["trunk_ok"])
                stats["trunk_ok"].append(tok[below] if early_exit else tok)
        if n_cycles > max_new + 8:
            break
    # alpha over rows that were still generating (masked rows commit 0 and
    # are excluded from the denominator; without early_exit this reduces to
    # the legacy mean over all row-cycles)
    alpha = (float(np.concatenate(stats["n_out"]).sum()) / act_cycles
             if act_cycles else 0.0)
    return {"tokens": out_buf[:, :max_new], "n_cycles": n_cycles,
            "alpha": alpha, "stats": stats}


@functools.partial(jax.jit,
                   static_argnames=("max_new", "max_len", "early_exit",
                                    "cache_impl", "page_size", "shard_tag"))
def _ondevice_loop(bundle: SpecBundle, prompts, key, max_new: int,
                   max_len: int, early_exit: bool = True,
                   cache_impl: str = "dense", page_size: int = 64,
                   shard_tag=None):
    """Prefill + full decode loop inside one ``lax.while_loop``.

    With ``early_exit`` the per-example ``EngineState.active`` mask is
    refreshed from ``filled < max_new`` every iteration: finished rows
    draft a degenerate root-only tree, commit nothing, and skip every
    KV / feature-cache write while the ``cond`` stays shape-stable.

    Returns (buf [B, max_new+g+1], n_cycles [], total_out [],
    act_row_cycles []) — all on device; the caller slices / casts.
    """
    b, _ = prompts.shape
    cap = buf_width = max_new + bundle.spec.gamma + 1
    cycle_cap = max_new + 9          # mirrors the host loop's bailout

    state = engine_init(bundle, b, max_len, cache_impl=cache_impl,
                        page_size=page_size)
    kpre, key = jax.random.split(key)
    state = prefill(bundle, state, prompts, key=kpre,
                    temperature=bundle.spec.temperature)
    buf = jnp.zeros((b, buf_width), jnp.int32).at[:, 0].set(state.anchor)
    filled = jnp.ones((b,), jnp.int32)

    def cond(carry):
        _, _, filled, _, n_cycles, _, _ = carry
        return (filled.min() < max_new) & (n_cycles < cycle_cap)

    def body(carry):
        state, buf, filled, key, n_cycles, total, act = carry
        below = filled < max_new
        if early_exit:
            state = state.replace(active=below)
        act = act + (below.sum(dtype=jnp.int32) if early_exit
                     else jnp.int32(b))
        key, sub = jax.random.split(key)
        state, out = decode_cycle(bundle, state, sub, collect_stats=False)
        t = out["tokens"].shape[1]
        idx = filled[:, None] + jnp.arange(t, dtype=jnp.int32)[None, :]
        valid = jnp.arange(t)[None, :] < out["n_out"][:, None]
        # out-of-budget / invalid slots scatter to index cap -> dropped
        wpos = jnp.where(valid, jnp.minimum(idx, cap), cap)
        bidx = jnp.broadcast_to(jnp.arange(b)[:, None], (b, t))
        buf = buf.at[bidx, wpos].set(out["tokens"], mode="drop")
        filled = jnp.minimum(filled + out["n_out"], buf_width)
        return (state, buf, filled, key, n_cycles + 1,
                total + out["n_out"].sum(), act)

    carry = (state, buf, filled, key, jnp.zeros((), jnp.int32),
             jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))
    _, buf, _, _, n_cycles, total, act = jax.lax.while_loop(cond, body,
                                                            carry)
    return buf, n_cycles, total, act


def generate_ondevice(bundle: SpecBundle, prompts, max_new: int, key=None,
                      max_len: Optional[int] = None,
                      early_exit: bool = True, cache_impl: str = "dense",
                      page_size: int = 64):
    """On-device generation: the whole decode loop runs inside a single
    ``jax.lax.while_loop`` with a padded output buffer — zero host syncs
    between cycles. Token-identical to :func:`generate` for the same key
    (same prefill/cycle key schedule, same commit rule); calibration stats
    are not collected on this path.

    early_exit: per-example masking of finished rows inside the loop (see
    :func:`_ondevice_loop`). Token output is identical with or without it
    for the same key; ``alpha`` is reported over active row-cycles only.

    Returns dict(tokens [B, max_new] device array, n_cycles, alpha).
    """
    b, p = prompts.shape
    g = bundle.spec.gamma
    key = key if key is not None else jax.random.PRNGKey(0)
    max_len = max_len or (p + max_new + 2 * g + 8)
    from repro.distributed import sharding as sh_lib
    buf, n_cycles, total, act = _ondevice_loop(bundle, prompts, key,
                                               max_new, max_len,
                                               early_exit=early_exit,
                                               cache_impl=cache_impl,
                                               page_size=page_size,
                                               shard_tag=sh_lib.mesh_tag())
    n = int(n_cycles)
    act = int(act)
    alpha = float(total) / act if act else 0.0
    return {"tokens": buf[:, :max_new], "n_cycles": n, "alpha": alpha}
