"""D2SD end-to-end decoding engine (paper §3.3).

One cycle = first draft (DFlash) -> top-K unmask -> second draft (VP,
batched) -> joint tree verification (cascade attention for attention
targets; branch-batched state-replay for SSM/hybrid targets, DESIGN §5.1)
-> longest-accepted-prefix commit.

Modes (SpecConfig.mode):
  d2sd          full pipeline (K VP branches)
  dflash        single-chain baseline (Table 1 / rows "DFlash")
  naive_k       trunk + K T=1 resamples from the SAME d1 forward (Table 5)
  dflash_second d2sd pipeline but drafter-1 weights as second drafter
                (Table 6 — wire bundle.d2_params = d1 params)
  eagle         autoregressive chain drafter baseline (EAGLE-style)
plus SpecConfig.third_level (Table 7) stacking one more VP level.

The K second-draft branches run in ONE drafter forward by concatenating
branches along the sequence axis with a block-diagonal bidirectional mask —
the batched pass of paper step (iii) without duplicating the feature cache.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig, SpecConfig
from repro.core import confidence as conf_lib
from repro.core import drafter as dr
from repro.core import tree as tree_lib
from repro.core import verify as verify_lib
from repro.models import lm


@dataclasses.dataclass(frozen=True)
class SpecBundle:
    target_cfg: ModelConfig
    d1_cfg: dr.DrafterConfig
    d2_cfg: dr.DrafterConfig
    spec: SpecConfig
    target_params: Any
    d1_params: Any
    d2_params: Any


jax.tree_util.register_pytree_node(
    SpecBundle,
    lambda s: ((s.target_params, s.d1_params, s.d2_params),
               (s.target_cfg, s.d1_cfg, s.d2_cfg, s.spec)),
    lambda aux, ch: SpecBundle(aux[0], aux[1], aux[2], aux[3], *ch),
)


def uses_tree_attention(cfg: ModelConfig) -> bool:
    """Tree-masked verification requires a pure-attention target."""
    kinds = set(cfg.pattern_for_depth())
    return not (kinds & {"recurrent", "rwkv"})


# ------------------------------------------------------------------ state --
def engine_init(bundle: SpecBundle, batch: int, max_len: int,
                ctx_len: int = 0):
    """Allocate caches for a request wave."""
    tcfg = bundle.target_cfg
    dt = jnp.dtype(tcfg.dtype)
    return {
        "target": lm.init_states(tcfg, batch, max_len, ctx_len=ctx_len,
                                 dtype=dt),
        "d1_feat": dr.init_feat_cache(bundle.d1_cfg, batch, max_len,
                                      dtype=jnp.dtype(bundle.d1_cfg.dtype)),
        "d2_feat": dr.init_feat_cache(bundle.d2_cfg, batch, max_len,
                                      dtype=jnp.dtype(bundle.d2_cfg.dtype)),
        "anchor": jnp.zeros((batch,), jnp.int32),
    }


def prefill(bundle: SpecBundle, est, prompts, key=None, ctx=None,
            temperature: float = 0.0):
    """Process prompts [B, P]; sets anchor = first generated token.

    cache_len is passed as a SCALAR 0: prefill always starts at offset 0, so
    the KV write lowers to dynamic-update-slice (partitionable along the
    kv_seq axis with zero communication) instead of a gather-scatter
    (§Perf: this was 2x9.6GB/layer of all-gather on 32k prefill).
    """
    out = lm.forward(bundle.target_params, prompts, bundle.target_cfg,
                     states=est["target"], cache_len=jnp.zeros((), jnp.int32),
                     write_kv=True, ctx=ctx, want_features=True, remat=False)
    b, p = prompts.shape
    positions = jnp.broadcast_to(jnp.arange(p)[None], (b, p))
    est = dict(est)
    est["target"] = out["states"]
    est["d1_feat"] = dr.extend_feat_cache(
        bundle.d1_params, bundle.d1_cfg, est["d1_feat"], out["features"],
        positions, jnp.full((b,), p))
    est["d2_feat"] = dr.extend_feat_cache(
        bundle.d2_params, bundle.d2_cfg, est["d2_feat"], out["features"],
        positions, jnp.full((b,), p))
    last = out["logits"][:, -1].astype(jnp.float32)
    if temperature > 0:
        est["anchor"] = jax.random.categorical(key, last / temperature)
    else:
        est["anchor"] = jnp.argmax(last, axis=-1).astype(jnp.int32)
    return est


# ------------------------------------------------------------- drafting ----
def _first_draft(bundle, est, key, temperature):
    """DFlash pass: returns (trunk [B,g-1], d1_logits [B,g,V])."""
    g = bundle.spec.gamma
    blk = dr.dflash_block(est["anchor"], g, bundle.d1_cfg.mask_token)
    logits = dr.drafter_forward(bundle.d1_params, bundle.d1_cfg, blk,
                                est["d1_feat"])
    if temperature > 0:
        trunk = jax.random.categorical(
            key, logits[:, 1:].astype(jnp.float32) / temperature)
    else:
        trunk = jnp.argmax(logits[:, 1:], axis=-1)
    return trunk.astype(jnp.int32), logits


def _second_draft(params, dcfg, est_feat, anchor, trunk, fork_idx, key,
                  temperature, feat_len):
    """VP pass, K branches in one forward via sequence-axis concatenation.

    Returns (branch_tokens [B,K,g-1], d2_logits [B,K,g,V]).
    """
    b, k = fork_idx.shape
    g = trunk.shape[-1] + 1
    vp_in = dr.vp_blocks(anchor, trunk, fork_idx, dcfg.mask_token)  # [B,K,g]
    flat = vp_in.reshape(b, k * g)
    # block-diagonal bidirectional mask (branches blind to each other)
    eye = jnp.eye(k, dtype=bool)
    bmask = jnp.repeat(jnp.repeat(eye, g, 0), g, 1)                 # [Kg,Kg]
    slots = jnp.tile(jnp.arange(g), k)[None, :]                     # [1,Kg]
    positions = feat_len[:, None] + slots
    logits = dr.drafter_forward(params, dcfg, flat, est_feat,
                                positions=positions, block_mask=bmask)
    logits = logits.reshape(b, k, g, -1)
    if temperature > 0:
        toks = jax.random.categorical(
            key, logits[:, :, 1:].astype(jnp.float32) / temperature)
    else:
        toks = jnp.argmax(logits[:, :, 1:], axis=-1)
    return toks.astype(jnp.int32), logits


# -------------------------------------------------------------- the cycle --
def decode_cycle(bundle: SpecBundle, est, key, collect_stats: bool = True):
    """One full speculative decoding cycle.

    Returns (est', out) with out = dict(tokens [B, gamma], n_out [B],
    n_acc [B], plus calibration stats when collect_stats).
    """
    spec = bundle.spec
    tcfg = bundle.target_cfg
    g, kbr = spec.gamma, spec.top_k_branches
    temp = spec.temperature
    b = est["anchor"].shape[0]
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    mode = spec.mode

    d2_logits = None
    fork_idx = None
    branch_tokens = None
    d3_info = None

    if mode == "eagle":
        trunk, d1_draft_logits = dr.ar_chain_draft(
            bundle.d1_params, bundle.d1_cfg, est["anchor"], est["d1_feat"],
            steps=g - 1, temperature=temp, key=k1)
        tree = tree_lib.chain_tree(est["anchor"], trunk)
        d1_logits = None
        conf = None
    else:
        trunk, d1_logits = _first_draft(bundle, est, k1, temp)
        conf = conf_lib.confidences(
            d1_logits[:, 1:],
            trunk if temp > 0 else None)                       # [B, g-1]
        if mode == "dflash":
            tree = tree_lib.chain_tree(est["anchor"], trunk)
        elif mode == "naive_k":
            # K extra branches = T=1 multinomial resamples of the same pass
            resampled = jax.random.categorical(
                k2, d1_logits[:, None, 1:, :].astype(jnp.float32)
                / max(temp, 1.0), shape=(b, kbr, g - 1))
            fork_idx = jnp.zeros((b, kbr), jnp.int32)
            branch_tokens = resampled.astype(jnp.int32)
            tree = tree_lib.comb_tree(est["anchor"], trunk, branch_tokens,
                                      fork_idx, g)
        else:  # d2sd / dflash_second
            r = conf_lib.boundary_posterior(conf)
            _, fork_idx = conf_lib.topk_prefixes(r, kbr)       # [B, K]
            branch_tokens, d2_logits = _second_draft(
                bundle.d2_params, bundle.d2_cfg, est["d2_feat"],
                est["anchor"], trunk, fork_idx, k3, temp,
                est["d2_feat"]["length"])
            tree = tree_lib.comb_tree(est["anchor"], trunk, branch_tokens,
                                      fork_idx, g)
            if spec.third_level:
                conf2 = conf_lib.confidences(
                    d2_logits[:, :, 1:].reshape(b * kbr, g - 1, -1),
                    branch_tokens.reshape(b * kbr, g - 1) if temp > 0
                    else None).reshape(b, kbr, g - 1)
                # only suffix slots (> fork) are third-level candidates
                slot = jnp.arange(1, g)[None, None, :]
                c2 = jnp.where(slot > fork_idx[:, :, None] + 1, conf2, 1.0)
                r2 = conf_lib.boundary_posterior(
                    c2.reshape(b * kbr, g - 1)).reshape(b, kbr, g - 1)
                # r2[..., i] = P(prefix of length i accepted); fork slot = i
                fork3 = jnp.argmax(r2, axis=-1).astype(jnp.int32)
                fork3 = jnp.clip(jnp.maximum(fork3, fork_idx + 1), 0, g - 2)
                # visible prefix for third branches = trunk up to fork_b +
                # branch b tokens up to fork3_b
                third_tokens, _ = _second_draft(
                    bundle.d2_params, bundle.d2_cfg, est["d2_feat"],
                    est["anchor"], _splice(trunk, branch_tokens, fork_idx),
                    fork3, k4, temp, est["d2_feat"]["length"])
                tree = tree_lib.extend_third_level(
                    tree, third_tokens, fork_idx, fork3, g)

    # ---------------- joint verification ----------------
    tmask = tree_lib.attention_mask(tree)
    length = est["target"]["length"]
    positions = tree_lib.positions(tree, length)
    if uses_tree_attention(tcfg):
        vout = lm.forward(bundle.target_params, tree.tokens, tcfg,
                          states=est["target"], write_kv=False,
                          extra_mask=tmask, positions=positions,
                          want_features=True, remat=False)
        logits = vout["logits"].astype(jnp.float32)
        logits = jnp.where(tree.valid[:, :, None], logits, -1e9)
        if temp > 0:
            if mode == "eagle":
                q = jax.nn.softmax(
                    d1_draft_logits.astype(jnp.float32) / temp, axis=-1)
                dprobs = jnp.concatenate([q[:, :1] * 0, q], axis=1)
            else:
                dprobs = _draft_probs(tree, d1_logits, d2_logits, fork_idx,
                                      g, temp, mode)
            res = verify_lib.sampling_verify(
                tree, logits, dprobs, k5,
                max_children=_max_children(mode, kbr, spec.third_level),
                temperature=temp)
        else:
            res = verify_lib.greedy_verify(tree, logits)
        # commit KV by gathering the accepted path from the verify pass
        n_commit = res["n_acc"] + 1
        new_target = lm.commit_kv(est["target"], vout["kv_outs"], tcfg,
                                  res["path"], n_commit)
        path_feats = jnp.take_along_axis(
            vout["features"], res["path"][..., None], axis=1)
    else:
        res, new_target, path_feats = _branch_batch_verify(
            bundle, est, tree, temp, k5)
        n_commit = res["n_acc"] + 1

    # ---------------- feature-cache extension ----------------
    fpos = length[:, None] + jnp.arange(res["path"].shape[1])[None, :]
    est2 = dict(est)
    est2["target"] = new_target
    est2["d1_feat"] = dr.extend_feat_cache(
        bundle.d1_params, bundle.d1_cfg, est["d1_feat"], path_feats, fpos,
        n_commit)
    est2["d2_feat"] = dr.extend_feat_cache(
        bundle.d2_params, bundle.d2_cfg, est["d2_feat"], path_feats, fpos,
        n_commit)
    est2["anchor"] = res["bonus"].astype(jnp.int32)

    # ---------------- outputs ----------------
    path_tokens = jnp.take_along_axis(tree.tokens, res["path"], axis=1)
    d_idx = jnp.arange(res["path"].shape[1])[None, :]
    out_tok = jnp.where(d_idx < res["n_acc"][:, None],
                        jnp.roll(path_tokens, -1, axis=1), 0)
    # slot d: accepted draft d+1 => path_tokens[d+1]; slot n_acc: bonus
    out_tok = jnp.where(d_idx == res["n_acc"][:, None],
                        res["bonus"][:, None], out_tok)
    out = {"tokens": out_tok, "n_out": res["n_acc"] + 1,
           "n_acc": res["n_acc"]}
    if collect_stats and conf is not None:
        # calibration: trunk confidences vs trunk-node acceptance (greedy ok)
        trunk_ok = res["ok"][:, 1:g] if res.get("ok") is not None else None
        out["conf"] = conf
        out["trunk_ok"] = trunk_ok
    return est2, out


def _splice(trunk, branch_tokens, fork_idx):
    """Per-branch completed block: trunk up to fork, branch tokens after.

    trunk [B,g-1], branch_tokens [B,K,g-1], fork_idx [B,K] -> [B,K,g-1]
    flattened to the 'trunk' argument shape expected by vp_blocks per branch.
    Used only to build third-level visible prefixes.
    """
    b, k = fork_idx.shape
    slot = jnp.arange(1, trunk.shape[1] + 1)[None, None, :]
    use_trunk = slot <= fork_idx[:, :, None]
    return jnp.where(use_trunk, trunk[:, None, :], branch_tokens)


def _max_children(mode, kbr, third_level):
    if mode in ("dflash", "eagle"):
        return 1
    base = kbr + 1
    return base + 1 if third_level else base


def _draft_probs(tree, d1_logits, d2_logits, fork_idx, g, temp, mode):
    """Assemble per-node drafter categoricals q_n [B,N,V] for sampling
    verification. Trunk slots from d1; branch slots from d2 (or d1 resample
    dist for naive_k)."""
    b, n = tree.tokens.shape
    v = d1_logits.shape[-1]
    q1 = jax.nn.softmax(d1_logits.astype(jnp.float32) / temp, axis=-1)
    slot = jnp.clip(tree.depth, 0, g - 1)                      # [B,N]
    q_trunk = jnp.take_along_axis(q1, slot[..., None], axis=1)
    if d2_logits is None:
        return q_trunk
    node = jnp.arange(n)
    k = d2_logits.shape[1]
    bidx = jnp.clip((node - g) // (g - 1), 0, k - 1)
    q2 = jax.nn.softmax(d2_logits.astype(jnp.float32) / temp, axis=-1)
    q2_flat = q2.reshape(b, k * g, v)
    sel = bidx[None, :] * g + slot                             # [B,N]
    q_branch = jnp.take_along_axis(q2_flat, sel[..., None], axis=1)
    is_trunk = (node < g)[None, :, None]
    return jnp.where(is_trunk, q_trunk, q_branch)


# ------------------------------------------------- SSM / hybrid verify -----
def _branch_batch_verify(bundle, est, tree: tree_lib.Tree, temp, key):
    """DESIGN §5.1: verification for recurrent targets.

    Enumerate the root-to-leaf token sequence of every branch (K+1 rows of
    length gamma), run the target once with branches folded into batch and
    per-row causal order (read-only states), pick the best row per example,
    then REPLAY the accepted path with write_kv + snap_at to advance all
    states by exactly n_commit tokens.
    """
    tcfg = bundle.target_cfg
    g = tree.max_depth + 1
    b, n = tree.tokens.shape
    # enumerate root-to-leaf token rows (comb: trunk + one per branch)
    rows = _paths_to_leaves(tree)                              # [B, R, g]
    r = rows.shape[1]
    row_tokens = jnp.take_along_axis(
        jnp.repeat(tree.tokens, r, axis=0),                    # [B*R, N]
        rows.reshape(b * r, g), axis=1)                        # [B*R, g]

    def rep(key_name, a):
        if not hasattr(a, "ndim") or a.ndim == 0:
            return a
        axis = 1 if key_name.startswith("p") else 0            # stacked periods
        return jnp.repeat(a, r, axis=axis)

    states_rep = {k2: (jax.tree.map(lambda a: rep(k2, a), v)
                       if isinstance(v, dict) else rep(k2, v))
                  for k2, v in est["target"].items()}
    vout = lm.forward(bundle.target_params, row_tokens, tcfg,
                      states=states_rep, write_kv=False, remat=False)
    logits = vout["logits"].astype(jnp.float32)                # [B*R, g, V]

    # NOTE temp>0: per-row chain rejection sampling would need per-row
    # residual bookkeeping; we use greedy acceptance on the sampled drafts
    # for SSM targets (approximation documented in DESIGN §5.1).
    pred_full = jnp.argmax(logits, axis=-1)                    # [B*R, g]
    ok = (pred_full[:, :-1] == row_tokens[:, 1:])
    # padded path entries repeat the leaf node; mask beyond leaf depth
    depth_leaf = jnp.take_along_axis(
        tree.depth, rows.reshape(b, r, g)[:, :, -1], axis=1)   # [B,R]
    ok = ok & (jnp.arange(g - 1)[None, :] <
               depth_leaf.reshape(b * r)[:, None])
    n_acc_r = jnp.cumprod(ok.astype(jnp.int32), axis=1).sum(1).reshape(b, r)
    best_row = jnp.argmax(n_acc_r, axis=1)
    n_acc = jnp.take_along_axis(n_acc_r, best_row[:, None], 1)[:, 0]
    path = jnp.take_along_axis(
        rows, best_row[:, None, None].repeat(g, 2), axis=1)[:, 0]  # [B,g]
    pred_best = jnp.take_along_axis(
        pred_full.reshape(b, r, g),
        best_row[:, None, None].repeat(g, 2), axis=1)[:, 0]    # [B,g]
    bonus = jnp.take_along_axis(pred_best, n_acc[:, None], axis=1)[:, 0]

    # replay accepted path to advance states by exactly n_commit
    n_commit = n_acc + 1
    path_tokens = jnp.take_along_axis(tree.tokens, path, axis=1)   # [B,g]
    rout = lm.forward(bundle.target_params, path_tokens, tcfg,
                      states=est["target"], write_kv=True,
                      snap_at=n_commit, attend_cache_on_write=True,
                      want_features=True, want_logits=False, remat=False)
    res = {"best": jnp.take_along_axis(path, n_acc[:, None], 1)[:, 0],
           "n_acc": n_acc, "path": path, "bonus": bonus.astype(jnp.int32),
           "accepted": None, "ok": None}
    return res, rout["states"], rout["features"]


def _paths_to_leaves(tree: tree_lib.Tree):
    """[B, R, g] node-index rows, one per leaf (trunk + each branch).

    Rows are recovered via parent walks from the deepest node of each branch
    segment; static for the comb/chain layouts produced in this module.
    """
    b, n = tree.tokens.shape
    g = tree.max_depth + 1
    # leaf candidates: trunk leaf = node g-1 ; branch leaves = last valid
    # node of each (g-1)-sized branch segment. For chain trees n == g (+0).
    if n == g:                                     # chain
        leaves = jnp.broadcast_to(jnp.arange(1) + (n - 1), (b, 1))
    else:
        k = (n - g) // (g - 1)
        seg_last = []
        for s in range(k):
            start = g + s * (g - 1)
            seg = jnp.arange(start, start + g - 1)
            validity = tree.valid[:, seg]
            # last valid node in segment (fork at g-2 -> single node)
            last_off = jnp.maximum(validity.sum(1) - 1, 0)
            seg_last.append(start + last_off)
        leaves = jnp.stack([jnp.full((b,), g - 1)] + seg_last, axis=1)
    rws = []
    cur = leaves
    rws.append(cur)
    for _ in range(g - 1):
        cur = jnp.maximum(
            jnp.take_along_axis(tree.parent, cur, axis=1), 0)
        rws.append(cur)
    up = jnp.stack(rws, axis=2)                    # [B, R, g] leaf->root
    depth_leaf = jnp.take_along_axis(tree.depth, leaves, axis=1)  # [B,R]
    d_idx = jnp.arange(g)[None, None, :]
    take = jnp.clip(depth_leaf[:, :, None] - d_idx, 0, g - 1)
    path = jnp.take_along_axis(up, take, axis=2)
    # pad beyond leaf depth with the leaf itself (token garbage but the
    # acceptance count never exceeds leaf depth because pred!=token there
    # cannot extend past the leaf — we additionally clamp below)
    path = jnp.where(d_idx <= depth_leaf[:, :, None], path,
                     leaves[:, :, None])
    return path


# -------------------------------------------------------------- generate ---
def generate(bundle: SpecBundle, prompts, max_new: int, key=None, ctx=None,
             max_len: Optional[int] = None, collect_stats: bool = True):
    """Generate up to ``max_new`` tokens for prompts [B, P] (host loop over
    jitted cycles). Returns dict(tokens [B, max_new], n_cycles, alpha, stats).
    """
    import numpy as np

    b, p = prompts.shape
    g = bundle.spec.gamma
    key = key if key is not None else jax.random.PRNGKey(0)
    max_len = max_len or (p + max_new + 2 * g + 8)
    est = engine_init(bundle, b, max_len)
    kpre, key = jax.random.split(key)
    est = prefill(bundle, est, prompts, key=kpre,
                  temperature=bundle.spec.temperature)
    first = np.asarray(est["anchor"])

    cycle = jax.jit(lambda e, k: decode_cycle(bundle, e, k, collect_stats))

    out_buf = np.zeros((b, max_new + g + 1), np.int32)
    out_buf[:, 0] = first
    filled = np.ones((b,), np.int64)
    n_cycles = 0
    stats = {"n_acc": [], "n_out": [], "conf": [], "trunk_ok": []}
    while filled.min() < max_new:
        key, sub = jax.random.split(key)
        est, out = cycle(est, sub)
        toks = np.asarray(out["tokens"])
        n_out = np.asarray(out["n_out"])
        for i in range(b):
            m = min(int(n_out[i]), out_buf.shape[1] - int(filled[i]))
            if m > 0:
                out_buf[i, filled[i]: filled[i] + m] = toks[i, :m]
        filled = np.minimum(filled + n_out, out_buf.shape[1])
        n_cycles += 1
        stats["n_acc"].append(np.asarray(out["n_acc"]))
        stats["n_out"].append(n_out)
        if collect_stats and "conf" in out:
            stats["conf"].append(np.asarray(out["conf"]))
            if out["trunk_ok"] is not None:
                stats["trunk_ok"].append(np.asarray(out["trunk_ok"]))
        if n_cycles > max_new + 8:
            break
    alpha = float(np.concatenate(stats["n_out"]).mean()) if stats["n_out"] else 0.0
    return {"tokens": out_buf[:, :max_new], "n_cycles": n_cycles,
            "alpha": alpha, "stats": stats}
