"""Pluggable draft strategies (paper §3.3 modes as registry entries).

A :class:`DraftStrategy` turns ``(bundle, state, key)`` into a
:class:`DraftResult` — a candidate :class:`~repro.core.tree.Tree` plus the
per-node proposal distributions the verifier needs for lossless sampling.
Each paper mode is one registered class; ``decode_cycle`` dispatches on
``SpecConfig.mode`` through :func:`get_strategy` with no branching of its
own, so a new drafter variant is a one-file plugin:

    @register_strategy("my_mode")
    class MyStrategy(DraftStrategy):
        def draft(self, bundle, state, key):
            ...
            return DraftResult(tree=tree, dprobs=q, conf=conf,
                               max_children=2)

Strategies also expose static cost metadata (``n_draft_passes`` /
``n_tree_nodes``) used by the roofline speedup model in benchmarks.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Type

import jax
import jax.numpy as jnp

from repro.config.base import SpecConfig
from repro.core import confidence as conf_lib
from repro.core import drafter as dr
from repro.core import tree as tree_lib
from repro.core.state import EngineState


@dataclasses.dataclass(frozen=True)
class DraftResult:
    """Output of one draft phase.

    tree:         candidate prefix tree rooted at the anchor.
    dprobs:       [B, N, V] per-node proposal categoricals q_n for sampling
                  verification (None under greedy decoding, temp == 0).
    conf:         [B, gamma-1] trunk confidences (Eq. 3) for calibration
                  stats; None for strategies without a diffusion trunk.
    max_children: static sibling bound for the verifier's child scan.
    """
    tree: tree_lib.Tree
    dprobs: Optional[jnp.ndarray]
    conf: Optional[jnp.ndarray]
    max_children: int


class DraftStrategy:
    """Protocol for draft-phase plugins. Subclass and register by name."""

    name: str = "?"

    def draft(self, bundle, state: EngineState, key) -> DraftResult:
        raise NotImplementedError

    # ---- static cost metadata (roofline model, benchmarks/common.py) ----
    def n_draft_passes(self, spec: SpecConfig) -> int:
        raise NotImplementedError

    def n_tree_nodes(self, spec: SpecConfig) -> int:
        raise NotImplementedError


_REGISTRY: Dict[str, Type[DraftStrategy]] = {}


def register_strategy(name: str):
    """Class decorator: ``@register_strategy("d2sd")``."""
    def deco(cls: Type[DraftStrategy]) -> Type[DraftStrategy]:
        # First registration names the class; aliases must not rename it
        # (strategy.name feeds logging/metrics).
        if cls.__dict__.get("name", "?") == "?":
            cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def get_strategy(name: str) -> DraftStrategy:
    try:
        return _REGISTRY[name]()
    except KeyError:
        raise KeyError(
            f"unknown draft strategy {name!r}; registered: "
            f"{sorted(_REGISTRY)}") from None


def registered_strategies() -> Dict[str, Type[DraftStrategy]]:
    return dict(_REGISTRY)


def mask_inactive(result: DraftResult, active) -> DraftResult:
    """Degenerate inactive rows' candidate trees to the root-only node.

    active: [B] bool. For rows with ``active=False`` every non-root node is
    invalidated (token zeroed, valid=False), so verification accepts
    nothing, the best path stays at the anchor, and the commit for that
    row is fully masked upstream (``decode_cycle`` zeroes ``n_out`` and
    keeps the anchor). Shape-stable: the node table keeps its static size,
    which is what lets the mask cross ``jit`` / ``while_loop`` boundaries.
    """
    t = result.tree
    keep = active[:, None] | (jnp.arange(t.n) == 0)[None, :]
    tree = tree_lib.Tree(tokens=jnp.where(keep, t.tokens, 0),
                         parent=t.parent, depth=t.depth,
                         valid=t.valid & keep, max_depth=t.max_depth)
    return dataclasses.replace(result, tree=tree)


# ----------------------------------------------------- shared draft steps --
def first_draft(bundle, state: EngineState, key, temperature):
    """DFlash pass: returns (trunk [B,g-1], d1_logits [B,g,V])."""
    g = bundle.spec.gamma
    blk = dr.dflash_block(state.anchor, g, bundle.d1_cfg.mask_token)
    logits = dr.drafter_forward(bundle.d1_params, bundle.d1_cfg, blk,
                                state.d1_feat)
    if temperature > 0:
        trunk = jax.random.categorical(
            key, logits[:, 1:].astype(jnp.float32) / temperature)
    else:
        trunk = jnp.argmax(logits[:, 1:], axis=-1)
    return trunk.astype(jnp.int32), logits


def second_draft(params, dcfg, feat_cache, anchor, trunk, fork_idx, key,
                 temperature, feat_len):
    """VP pass, K branches in one forward via sequence-axis concatenation.

    Returns (branch_tokens [B,K,g-1], d2_logits [B,K,g,V]).
    """
    b, k = fork_idx.shape
    g = trunk.shape[-1] + 1
    vp_in = dr.vp_blocks(anchor, trunk, fork_idx, dcfg.mask_token)  # [B,K,g]
    flat = vp_in.reshape(b, k * g)
    # block-diagonal bidirectional mask (branches blind to each other)
    eye = jnp.eye(k, dtype=bool)
    bmask = jnp.repeat(jnp.repeat(eye, g, 0), g, 1)                 # [Kg,Kg]
    slots = jnp.tile(jnp.arange(g), k)[None, :]                     # [1,Kg]
    positions = feat_len[:, None] + slots
    logits = dr.drafter_forward(params, dcfg, flat, feat_cache,
                                positions=positions, block_mask=bmask)
    logits = logits.reshape(b, k, g, -1)
    if temperature > 0:
        toks = jax.random.categorical(
            key, logits[:, :, 1:].astype(jnp.float32) / temperature)
    else:
        toks = jnp.argmax(logits[:, :, 1:], axis=-1)
    return toks.astype(jnp.int32), logits


def _splice(trunk, branch_tokens, fork_idx):
    """Per-branch completed block: trunk up to fork, branch tokens after.

    trunk [B,g-1], branch_tokens [B,K,g-1], fork_idx [B,K] -> [B,K,g-1]
    flattened to the 'trunk' argument shape expected by vp_blocks per branch.
    Used only to build third-level visible prefixes.
    """
    slot = jnp.arange(1, trunk.shape[1] + 1)[None, None, :]
    use_trunk = slot <= fork_idx[:, :, None]
    return jnp.where(use_trunk, trunk[:, None, :], branch_tokens)


def comb_draft_probs(tree, d1_logits, d2_logits, g, temp):
    """Assemble per-node drafter categoricals q_n [B,N,V] for sampling
    verification. Trunk slots from d1; branch slots from d2 (or d1 resample
    dist for naive_k, d2_logits=None)."""
    b, n = tree.tokens.shape
    v = d1_logits.shape[-1]
    q1 = jax.nn.softmax(d1_logits.astype(jnp.float32) / temp, axis=-1)
    slot = jnp.clip(tree.depth, 0, g - 1)                      # [B,N]
    q_trunk = jnp.take_along_axis(q1, slot[..., None], axis=1)
    if d2_logits is None:
        return q_trunk
    node = jnp.arange(n)
    k = d2_logits.shape[1]
    bidx = jnp.clip((node - g) // (g - 1), 0, k - 1)
    q2 = jax.nn.softmax(d2_logits.astype(jnp.float32) / temp, axis=-1)
    q2_flat = q2.reshape(b, k * g, v)
    sel = bidx[None, :] * g + slot                             # [B,N]
    q_branch = jnp.take_along_axis(q2_flat, sel[..., None], axis=1)
    is_trunk = (node < g)[None, :, None]
    return jnp.where(is_trunk, q_trunk, q_branch)


# ------------------------------------------------------------ strategies ---
@register_strategy("dflash")
class DFlashStrategy(DraftStrategy):
    """Single-chain first-draft baseline (Table 1 rows "DFlash")."""

    def draft(self, bundle, state, key):
        spec = bundle.spec
        temp = spec.temperature
        k1, _ = jax.random.split(key)
        trunk, d1_logits = first_draft(bundle, state, k1, temp)
        conf = conf_lib.confidences(d1_logits[:, 1:],
                                    trunk if temp > 0 else None)
        tree = tree_lib.chain_tree(state.anchor, trunk)
        dprobs = (comb_draft_probs(tree, d1_logits, None, spec.gamma, temp)
                  if temp > 0 else None)
        return DraftResult(tree=tree, dprobs=dprobs, conf=conf,
                           max_children=1)

    def n_draft_passes(self, spec):
        return 1

    def n_tree_nodes(self, spec):
        return spec.gamma


@register_strategy("eagle")
class EagleStrategy(DraftStrategy):
    """Autoregressive chain drafter baseline (EAGLE-style)."""

    def draft(self, bundle, state, key):
        spec = bundle.spec
        g, temp = spec.gamma, spec.temperature
        k1, _ = jax.random.split(key)
        trunk, chain_logits = dr.ar_chain_draft(
            bundle.d1_params, bundle.d1_cfg, state.anchor, state.d1_feat,
            steps=g - 1, temperature=temp, key=k1)
        tree = tree_lib.chain_tree(state.anchor, trunk)
        dprobs = None
        if temp > 0:
            q = jax.nn.softmax(chain_logits.astype(jnp.float32) / temp,
                               axis=-1)
            dprobs = jnp.concatenate([q[:, :1] * 0, q], axis=1)
        return DraftResult(tree=tree, dprobs=dprobs, conf=None,
                           max_children=1)

    def n_draft_passes(self, spec):
        return spec.gamma - 1

    def n_tree_nodes(self, spec):
        return spec.gamma


@register_strategy("naive_k")
class NaiveKStrategy(DraftStrategy):
    """Trunk + K T=1 multinomial resamples of the SAME d1 pass (Table 5)."""

    def draft(self, bundle, state, key):
        spec = bundle.spec
        g, kbr, temp = spec.gamma, spec.top_k_branches, spec.temperature
        b = state.batch
        k1, k2 = jax.random.split(key)
        trunk, d1_logits = first_draft(bundle, state, k1, temp)
        conf = conf_lib.confidences(d1_logits[:, 1:],
                                    trunk if temp > 0 else None)
        resampled = jax.random.categorical(
            k2, d1_logits[:, None, 1:, :].astype(jnp.float32)
            / max(temp, 1.0), shape=(b, kbr, g - 1))
        fork_idx = jnp.zeros((b, kbr), jnp.int32)
        tree = tree_lib.comb_tree(state.anchor, trunk,
                                  resampled.astype(jnp.int32), fork_idx, g)
        dprobs = (comb_draft_probs(tree, d1_logits, None, g, temp)
                  if temp > 0 else None)
        return DraftResult(tree=tree, dprobs=dprobs, conf=conf,
                           max_children=kbr + 1)

    def n_draft_passes(self, spec):
        return 1

    def n_tree_nodes(self, spec):
        return spec.gamma + spec.top_k_branches * (spec.gamma - 1)


@register_strategy("d2sd")
class D2SDStrategy(DraftStrategy):
    """Full dual-diffusion pipeline: DFlash trunk -> Eq. 5 top-K forks ->
    batched VP second draft (+ optional third level, Table 7)."""

    def draft(self, bundle, state, key):
        spec = bundle.spec
        g, kbr, temp = spec.gamma, spec.top_k_branches, spec.temperature
        b = state.batch
        k1, k3, k4 = jax.random.split(key, 3)
        trunk, d1_logits = first_draft(bundle, state, k1, temp)
        conf = conf_lib.confidences(d1_logits[:, 1:],
                                    trunk if temp > 0 else None)
        r = conf_lib.boundary_posterior(conf)
        _, fork_idx = conf_lib.topk_prefixes(r, kbr)           # [B, K]
        branch_tokens, d2_logits = second_draft(
            bundle.d2_params, bundle.d2_cfg, state.d2_feat,
            state.anchor, trunk, fork_idx, k3, temp,
            state.d2_feat["length"])
        tree = tree_lib.comb_tree(state.anchor, trunk, branch_tokens,
                                  fork_idx, g)
        max_children = kbr + 1
        if spec.third_level:
            conf2 = conf_lib.confidences(
                d2_logits[:, :, 1:].reshape(b * kbr, g - 1, -1),
                branch_tokens.reshape(b * kbr, g - 1) if temp > 0
                else None).reshape(b, kbr, g - 1)
            # only suffix slots (> fork) are third-level candidates
            slot = jnp.arange(1, g)[None, None, :]
            c2 = jnp.where(slot > fork_idx[:, :, None] + 1, conf2, 1.0)
            r2 = conf_lib.boundary_posterior(
                c2.reshape(b * kbr, g - 1)).reshape(b, kbr, g - 1)
            # r2[..., i] = P(prefix of length i accepted); fork slot = i
            fork3 = jnp.argmax(r2, axis=-1).astype(jnp.int32)
            fork3 = jnp.clip(jnp.maximum(fork3, fork_idx + 1), 0, g - 2)
            # visible prefix for third branches = trunk up to fork_b +
            # branch b tokens up to fork3_b
            third_tokens, _ = second_draft(
                bundle.d2_params, bundle.d2_cfg, state.d2_feat,
                state.anchor, _splice(trunk, branch_tokens, fork_idx),
                fork3, k4, temp, state.d2_feat["length"])
            tree = tree_lib.extend_third_level(
                tree, third_tokens, fork_idx, fork3, g)
            max_children += 1
        dprobs = (comb_draft_probs(tree, d1_logits, d2_logits, g, temp)
                  if temp > 0 else None)
        return DraftResult(tree=tree, dprobs=dprobs, conf=conf,
                           max_children=max_children)

    def n_draft_passes(self, spec):
        return 3 if spec.third_level else 2

    def n_tree_nodes(self, spec):
        base = spec.gamma + spec.top_k_branches * (spec.gamma - 1)
        if spec.third_level:
            base += spec.top_k_branches * (spec.gamma - 1)
        return base


@register_strategy("dflash_second")
class DFlashSecondStrategy(D2SDStrategy):
    """Table 6 ablation: d2sd pipeline with drafter-1 weights reused as the
    second drafter (wire bundle.d2_params = d1 params; the draft phase is
    identical to d2sd)."""
