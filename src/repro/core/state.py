"""Typed decode-engine state.

:class:`EngineState` is the single pytree that flows through the decode
loop — target model states (KV caches / recurrent states), the two
drafter feature caches, and the anchor token of the next block. It is
frozen and pytree-registered, so it jits, donates, and crosses a
``jax.lax.while_loop`` boundary unchanged; every cycle produces a *new*
EngineState via :meth:`replace`.

Field shapes are allocated once per request wave by :func:`engine_init`
(static ``batch`` / ``max_len``), which is what lets the whole generation
loop run on device without host round-trips.

KV storage is pluggable (``cache_impl``): ``dense`` keeps per-row
contiguous buffers; ``paged`` backs the target global-attention KV and
both feature caches with shared page pools + per-row page tables (see
``repro.models.kvcache``). In paged mode slot refill is copy-free:
:func:`row_template` builds a batch-1 state that *shares* the wave's
pools with a one-row page table of freshly allocated pages, ``prefill``
writes the prompt KV straight into those pages, and :meth:`adopt_row`
then only patches the page-table row and splices the small dense leaves.
:func:`install_row` wraps that sequence in a donated ``jit`` so the whole
install lowers to in-place page writes (the dense path gets the same
donated treatment, turning the old full-state ``adopt_row`` copy into an
in-place row splice).

Pool ownership is external (the borrowed-pool contract): a serving wave's
state *borrows* its page-pool buffers from the engine-lifetime
``PagePool`` — :func:`capture_pools` harvests them at wave turnover and
:func:`engine_init` re-adopts them directly into the next wave's state
(``pools=``, skipping the transient zero allocation; :func:`adopt_pools`
is the post-hoc variant for states built elsewhere), so pages the radix
prefix cache retained keep their KV across ``start_wave``. The same
contract extends INSIDE a wave to overlapped installs: every install
(:func:`install_row` / the batched :func:`install_rows`) donates the wave
state and writes only freshly allocated pages plus its own page-table
rows and dense-leaf rows, so the host may dispatch installs for idle
slots while a decode cycle for the *other* rows is still in flight on
device — the two operations touch disjoint pages/rows, and JAX's async
dispatch serializes them on the donated state without a host sync. The
only host read of device state an install needs (the prefilled anchor
token) is deferred by the engine to the next retire boundary.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import drafter as dr
from repro.models import kvcache as kvc
from repro.models import lm


def _feat_axis(name: str) -> int:
    """Batch axis of a feature-cache leaf by key: "length" and "pt" are
    batch-leading [B, ...], k/v are [L, B, ...]. (Paged traversals handle
    "pt" before consulting this — the 0 here keeps the contract honest for
    any caller that does not.)"""
    return 0 if name in ("length", "pt") else 1


@dataclasses.dataclass(frozen=True)
class EngineState:
    """Per-wave decode state (all leaves batched on axis 0 or equivalent).

    target:  ``lm.init_states`` dict — per-layer KV caches / recurrent
             states plus per-example committed ``length`` [B].
    d1_feat: first-drafter feature cache (``drafter.init_feat_cache``).
    d2_feat: second-drafter feature cache.
    anchor:  [B] int32 — the bonus token that roots the next draft block.
    active:  [B] bool — rows still generating. Inactive rows draft a
             degenerate root-only tree, commit zero tokens, and skip every
             KV / feature-cache write, so a finished (or idle) row costs
             no state mutation inside the decode loop and its slot can be
             re-prefilled in place via :meth:`adopt_row`.
    """
    target: Dict[str, Any]
    d1_feat: Dict[str, Any]
    d2_feat: Dict[str, Any]
    anchor: jnp.ndarray
    active: jnp.ndarray

    @property
    def length(self) -> jnp.ndarray:
        """[B] number of committed target positions."""
        return self.target["length"]

    @property
    def batch(self) -> int:
        return self.anchor.shape[0]

    @property
    def cache_impl(self) -> str:
        """"dense" | "paged" — detected structurally (feature caches are
        paged exactly when the wave is)."""
        return "paged" if kvc.is_paged(self.d1_feat) else "dense"

    @property
    def max_len(self) -> int:
        """Static logical cache capacity this state was allocated with
        (max_pages * page_size when paged)."""
        if kvc.is_paged(self.d1_feat):
            return kvc.logical_len(self.d1_feat)
        return self.d1_feat["k"].shape[2]

    @property
    def page_size(self) -> int:
        return kvc.page_geometry(self.d1_feat)[0]

    @property
    def max_pages(self) -> int:
        return kvc.page_geometry(self.d1_feat)[1]

    def replace(self, **kw) -> "EngineState":
        return dataclasses.replace(self, **kw)

    def adopt_row(self, row, other: "EngineState",
                  src_row: int = 0) -> "EngineState":
        """Splice ``other``'s ``src_row`` into this state's ``row``.

        This is the slot-refill primitive: a retired request's row is
        overwritten with a freshly prefilled single-request state (same
        ``max_len``), leaving every other row untouched. ``row`` may be a
        traced index; ``other`` is typically batch-1.

        Paged caches follow the shared-pool contract: ``other`` must hold
        the *same* (updated) pools as ``self`` — built via
        :func:`row_template` — so its k/v pool arrays pass through
        wholesale and only the page-table row is spliced. Under a donated
        jit that makes the adopt an in-place row/table write instead of a
        full-state copy.
        """
        return EngineState(
            target=_adopt_dict(self.target, other.target, row, src_row,
                               lm.state_batch_axis),
            d1_feat=_adopt_block(self.d1_feat, other.d1_feat, row, src_row,
                                 _feat_axis),
            d2_feat=_adopt_block(self.d2_feat, other.d2_feat, row, src_row,
                                 _feat_axis),
            anchor=_splice_row(self.anchor, other.anchor, row, src_row, 0),
            active=_splice_row(self.active, other.active, row, src_row, 0),
        )


jax.tree_util.register_pytree_node(
    EngineState,
    lambda s: ((s.target, s.d1_feat, s.d2_feat, s.anchor, s.active), None),
    lambda _, ch: EngineState(*ch),
)


def _splice_row(dst, src, row, src_row, axis):
    """Write src[..., src_row, ...] into dst at ``row`` along ``axis``."""
    if not hasattr(dst, "ndim") or dst.ndim == 0:
        return dst
    sl = jax.lax.index_in_dim(src, src_row, axis, keepdims=False)
    return jax.lax.dynamic_update_index_in_dim(
        dst, sl.astype(dst.dtype), row, axis)


def _adopt_block(dst, src, row, src_row, axis_for):
    """Adopt one block/cache dict; ``axis_for(key)`` gives the batch axis
    of dense leaves. Paged pools pass through from ``src`` (shared-pool
    contract) and the page table splices along its own batch axis."""
    out = {}
    paged = kvc.is_paged(dst)
    for name, v in dst.items():
        if paged and name in ("k", "v"):
            out[name] = src[name]
        elif name == "pt":
            out[name] = _splice_row(v, src[name], row, src_row, v.ndim - 2)
        else:
            ax = axis_for(name)
            out[name] = jax.tree.map(
                lambda d, s, a=ax: _splice_row(d, s, row, src_row, a),
                v, src[name])
    return out


def _adopt_dict(dst, src, row, src_row, axis_for):
    out = {}
    for name, v in dst.items():
        if isinstance(v, dict):
            out[name] = _adopt_block(v, src[name], row, src_row,
                                     lambda _n, a=axis_for(name): a)
        else:
            out[name] = _splice_row(v, src[name], row, src_row,
                                    axis_for(name))
    return out


def engine_init(bundle, batch: int, max_len: int, ctx_len: int = 0,
                cache_impl: str = "dense", page_size: int = 64,
                pool_pages=None, page_table=None,
                pools: Optional[Dict[str, Any]] = None) -> EngineState:
    """Allocate caches for a request wave (``bundle``: pipeline.SpecBundle).

    cache_impl="paged": every paged cache of the wave (target global KV
    and both feature caches) shares ONE page-id space: ``page_table``
    [B, max_pages] applies to all of them, and ``pool_pages`` sizes each
    pool. Defaults reproduce the allocator-free identity layout (row i
    owns pages [i*MP, (i+1)*MP)) used by ``generate``; the serving engine
    passes an initially-unallocated table and patches rows at install.

    pools: retained device pool buffers from :func:`capture_pools` of the
    previous wave (the borrowed-pool contract). Caches named in it adopt
    the retained buffers DIRECTLY at init — the transient pool-sized zero
    allocation a post-hoc ``adopt_pools`` would immediately discard is
    never materialized. Geometry must match the allocation this call
    would have made; the caller must drop its own reference once the
    wave's first donated install consumes the state.
    """
    tcfg = bundle.target_cfg
    dt = jnp.dtype(tcfg.dtype)
    if cache_impl == "paged":
        pool_pages, page_table = kvc.default_page_layout(
            batch, max_len, page_size, pool_pages, page_table)
    else:
        assert not pools, "retained pool buffers require cache_impl='paged'"
    pools = pools or {}
    kw = dict(cache_impl=cache_impl, page_size=page_size,
              pool_pages=pool_pages, page_table=page_table)
    tgt_pools = {name[len("target/"):]: kv for name, kv in pools.items()
                 if name.startswith("target/")}
    return EngineState(
        target=lm.init_states(tcfg, batch, max_len, ctx_len=ctx_len,
                              dtype=dt, ext_pools=tgt_pools or None, **kw),
        d1_feat=dr.init_feat_cache(bundle.d1_cfg, batch, max_len,
                                   dtype=jnp.dtype(bundle.d1_cfg.dtype),
                                   ext_pool=pools.get("d1_feat"), **kw),
        d2_feat=dr.init_feat_cache(bundle.d2_cfg, batch, max_len,
                                   dtype=jnp.dtype(bundle.d2_cfg.dtype),
                                   ext_pool=pools.get("d2_feat"), **kw),
        anchor=jnp.zeros((batch,), jnp.int32),
        active=jnp.ones((batch,), bool),
    )


def prefill(bundle, state: EngineState, prompts, key=None, ctx=None,
            temperature: float = 0.0, true_len=None,
            start=None) -> EngineState:
    """Process prompts [B, P]; sets anchor = first generated token.

    cache_len is passed as a SCALAR 0: prefill always starts at offset 0, so
    the KV write lowers to dynamic-update-slice (partitionable along the
    kv_seq axis with zero communication) instead of a gather-scatter
    (§Perf: this was 2x9.6GB/layer of all-gather on 32k prefill).

    true_len ([B] or scalar, traced): ``prompts`` is padded to a bucketed
    length and only the first ``true_len`` tokens per row are real — KV
    writes and feature-cache entries beyond are dropped, recurrent states
    snapshot at exactly ``true_len`` consumed tokens, the committed
    ``length`` advances by ``true_len``, and the anchor reads the logits
    at position ``true_len - 1``. Lets one install trace serve every
    prompt length in a bucket (O(buckets) compiles, not O(lengths)).

    start ([B] or scalar, traced): warm start — the caches already hold
    ``start`` committed positions (a prefix-cache hit spliced the shared
    pages into this row), ``prompts`` is only the *uncached suffix*, and
    the forward attends [cache ++ suffix] with positions offset by
    ``start``. Caller must have set the state's lengths to ``start``.
    """
    b, p = prompts.shape
    warm = start is not None
    if warm:
        cl = jnp.broadcast_to(jnp.asarray(start, jnp.int32).reshape(-1), (b,))
    else:
        cl = jnp.zeros((), jnp.int32)
    snap = None
    if true_len is not None:
        snap = jnp.broadcast_to(jnp.asarray(true_len, jnp.int32).reshape(-1),
                                (b,))
    out = lm.forward(bundle.target_params, prompts, bundle.target_cfg,
                     states=state.target, cache_len=cl,
                     write_kv=True, snap_at=snap, attend_cache_on_write=warm,
                     ctx=ctx, want_features=True, remat=False)
    base = cl[:, None] if warm else jnp.zeros((b, 1), jnp.int32)
    positions = base + jnp.arange(p, dtype=jnp.int32)[None, :]
    counts = snap if snap is not None else jnp.full((b,), p)
    d1_feat = dr.extend_feat_cache(
        bundle.d1_params, bundle.d1_cfg, state.d1_feat, out["features"],
        positions, counts)
    d2_feat = dr.extend_feat_cache(
        bundle.d2_params, bundle.d2_cfg, state.d2_feat, out["features"],
        positions, counts)
    if snap is None:
        last = out["logits"][:, -1].astype(jnp.float32)
    else:
        last = jnp.take_along_axis(
            out["logits"], jnp.maximum(snap - 1, 0)[:, None, None],
            axis=1)[:, 0].astype(jnp.float32)
    if temperature > 0:
        anchor = jax.random.categorical(key, last / temperature)
    else:
        anchor = jnp.argmax(last, axis=-1)
    return state.replace(target=out["states"], d1_feat=d1_feat,
                         d2_feat=d2_feat,
                         anchor=anchor.astype(jnp.int32))


# ------------------------------------------------------- slot install -------
def _zeros_rows(a, ax, k):
    if not hasattr(a, "ndim") or a.ndim == 0:
        return a
    return jnp.zeros(a.shape[:ax] + (k,) + a.shape[ax + 1:], a.dtype)


def rows_template(state: EngineState, row_tables) -> EngineState:
    """Batch-K install target *sharing* this wave's page pools.

    ``row_tables`` [K, max_pages] int32: one row of physical pages per
    incoming request (unallocated slots = :data:`kvc.PAGE_SENTINEL`).
    Dense leaves (local rolling KV, recurrent states, lengths, anchor)
    become zeroed batch-K rows; paged pools are passed by reference with
    the K-row table, so a ``prefill`` on the result writes every
    request's KV directly into the wave's pools at its own pages.
    ``adopt_row(..., src_row=i)`` afterwards only patches page-table rows
    and splices the small dense leaves — the copy-free refill contract,
    K requests per donated trace.
    """
    rt = jnp.asarray(row_tables, jnp.int32)                 # [K, MP]
    k = rt.shape[0]

    def blk(d, axis_for):
        paged = kvc.is_paged(d)
        out = {}
        for name, v in d.items():
            if paged and name in ("k", "v"):
                out[name] = v
            elif name == "pt":
                out[name] = jnp.broadcast_to(
                    rt, v.shape[:-2] + (k, v.shape[-1]))
            else:
                ax = axis_for(name)
                out[name] = jax.tree.map(
                    lambda a, x=ax: _zeros_rows(a, x, k), v)
        return out

    target = {}
    for name, v in state.target.items():
        if isinstance(v, dict):
            target[name] = blk(v, lambda _n, a=lm.state_batch_axis(name): a)
        else:
            target[name] = _zeros_rows(v, 0, k)
    return EngineState(
        target=target,
        d1_feat=blk(state.d1_feat, _feat_axis),
        d2_feat=blk(state.d2_feat, _feat_axis),
        anchor=jnp.zeros((k,), jnp.int32),
        active=jnp.ones((k,), bool),
    )


def row_template(state: EngineState, row_table) -> EngineState:
    """Batch-1 :func:`rows_template` (``row_table`` [max_pages])."""
    return rows_template(state, jnp.asarray(row_table, jnp.int32)[None])


def _with_lengths(sub: EngineState, length) -> EngineState:
    """Batch-K state with every committed-length leaf set to ``length``
    ([K] vector or scalar — warm install: the spliced shared pages
    already hold that many committed positions per row)."""
    k = sub.anchor.shape[0]
    lk = jnp.broadcast_to(jnp.asarray(length, jnp.int32).reshape(-1), (k,))
    return sub.replace(target={**sub.target, "length": lk},
                       d1_feat={**sub.d1_feat, "length": lk},
                       d2_feat={**sub.d2_feat, "length": lk})


def _map_paged_pools(state: EngineState, fn) -> EngineState:
    """Apply ``fn(pool)`` to the k/v pool of every paged cache dict."""
    def blk(d):
        if not kvc.is_paged(d):
            return d
        return {**d, "k": fn(d["k"]), "v": fn(d["v"])}

    target = {name: (blk(v) if isinstance(v, dict) else v)
              for name, v in state.target.items()}
    return state.replace(target=target, d1_feat=blk(state.d1_feat),
                         d2_feat=blk(state.d2_feat))


# ------------------------------------------------- borrowed-pool contract ---
def capture_pools(state: EngineState) -> Dict[str, Any]:
    """Harvest the physical k/v page-pool buffers of every paged cache.

    The pool buffers ``[*lead, P, page, H, D]`` are batch-free — only the
    page table and the dense leaves depend on the wave geometry — so an
    engine-lifetime :class:`~repro.models.kvcache.PagePool` can carry them
    *across* waves: at wave turnover the engine captures them here and
    re-installs them into the next wave's freshly allocated state via
    :func:`adopt_pools`, keeping every page the radix prefix cache owns
    bit-intact (cached prefixes survive ``start_wave``). Keys name the
    cache ("target/<entry>", "d1_feat", "d2_feat"); values are ``(k, v)``.

    Per-shard contract (mesh residency): the captured values are the
    device buffers THEMSELVES, placement included — on a mesh each
    buffer's payload is laid out along the ``kv_seq`` axis
    (:func:`~repro.models.kvcache.shard_pool`), and carrying the buffer
    across the turnover carries that per-shard layout with it, zero-copy
    (no gather to host, no resharding). Pool geometry stays the GLOBAL
    logical shape throughout; only the bytes are distributed.
    """
    pools: Dict[str, Any] = {}
    for name, v in state.target.items():
        if isinstance(v, dict) and kvc.is_paged(v):
            pools[f"target/{name}"] = (v["k"], v["v"])
    if kvc.is_paged(state.d1_feat):
        pools["d1_feat"] = (state.d1_feat["k"], state.d1_feat["v"])
    if kvc.is_paged(state.d2_feat):
        pools["d2_feat"] = (state.d2_feat["k"], state.d2_feat["v"])
    return pools


def adopt_pools(state: EngineState, pools: Dict[str, Any]) -> EngineState:
    """Install externally owned pool buffers (from :func:`capture_pools`)
    into a freshly initialized wave state — the borrowed-pool contract:
    the wave does not own its page pools, the engine does.

    Pool geometry (pool_pages / page_size / heads) must match the state's
    allocation; batch size and table width may differ freely. The caller
    must drop its own reference after the wave's first donated install
    consumes the state (the engine re-captures at wave turnover).

    Shapes are compared against the GLOBAL logical geometry: a borrowed
    buffer whose payload is sharded along ``kv_seq`` still reports its
    global shape, so the adoption check (and the zero-copy pass-through —
    the adopted array is installed as-is, never re-``device_put``) is
    layout-agnostic. Do not mix buffers captured under one mesh into an
    engine built under another; the engine's construction-time context is
    the single source of placement truth.
    """
    def blk(d, path):
        if not kvc.is_paged(d) or path not in pools:
            return d
        k, v = pools[path]
        assert k.shape == d["k"].shape and k.dtype == d["k"].dtype, (
            "borrowed pool geometry mismatch", path, k.shape, d["k"].shape)
        return {**d, "k": k, "v": v}

    target = {name: (blk(v, f"target/{name}") if isinstance(v, dict) else v)
              for name, v in state.target.items()}
    return state.replace(target=target,
                         d1_feat=blk(state.d1_feat, "d1_feat"),
                         d2_feat=blk(state.d2_feat, "d2_feat"))


def _cow_copy_impl(state: EngineState, src, dst) -> EngineState:
    return _map_paged_pools(state, lambda p: kvc.copy_page(p, src, dst))


_cow_copy_donated = functools.partial(
    jax.jit, donate_argnames=("state",))(_cow_copy_impl)


def cow_copy_page(state: EngineState, src, dst) -> EngineState:
    """Copy physical page ``src`` -> ``dst`` in EVERY paged pool of the
    wave (target global-attention KV and both drafter feature caches) —
    the copy-on-write step of a prefix-cache hit whose matched length
    ends inside a page. ``state`` is DONATED (in-place page write); one
    trace per state shapes (``src``/``dst`` are traced)."""
    assert state.cache_impl == "paged", "COW only exists for paged caches"
    return _cow_copy_donated(state, jnp.asarray(src, jnp.int32),
                             jnp.asarray(dst, jnp.int32))


def _install_impl(bundle, state, row, prompt, key, row_table,
                  temperature: float, ctx_len: int, prefix_hit=None,
                  true_len=None, shard_tag=None):
    # shard_tag: static cache-splitter only (sharding.mesh_tag()) — the
    # trace reads the ambient mesh context (constrain / shard_map hooks),
    # which jit's aval-keyed cache cannot see; threading the tag lets one
    # process hold sharded and unsharded specializations side by side.
    del shard_tag
    if state.cache_impl == "paged":
        sub = row_template(state, row_table)
    else:
        sub = engine_init(bundle, 1, state.max_len, ctx_len=ctx_len)
    if prefix_hit is not None:
        sub = _with_lengths(sub, prefix_hit)
    sub = prefill(bundle, sub, prompt[None, :], key=key,
                  temperature=temperature, true_len=true_len,
                  start=prefix_hit)
    return state.adopt_row(row, sub)


# Donated install: `state` is consumed — XLA rewrites the row / tail pages
# in place instead of copying the wave state. One trace per
# (prompt-bucket length, warm/cold, state shapes); `row`, `row_table`,
# `prefix_hit` and `true_len` are traced.
_install_row_donated = functools.partial(
    jax.jit, static_argnames=("temperature", "ctx_len", "shard_tag"),
    donate_argnames=("state",))(_install_impl)


def install_row(bundle, state: EngineState, row, prompt, key=None,
                temperature: float = 0.0, row_table=None,
                ctx_len: int = 0, prefix_hit=None,
                true_len=None, shard_tag=None) -> EngineState:
    """Serving fast path: prefill ``prompt`` into ``row`` with the input
    ``state`` DONATED (caller must drop its reference). Paged states
    require ``row_table`` (the allocated pages); dense states splice via
    an in-place row write.

    prefix_hit (paged only): number of committed tokens already present
    in the row's spliced pages (a prefix-cache hit) — ``prompt`` then
    holds only the *uncached suffix* and the batch-1 prefill runs over
    it alone, attending to the shared prefix KV. Token-identical to a
    cold install of the full prompt (asserted by tests/serving bench).

    true_len: real token count when ``prompt`` is padded to a length
    bucket (see :func:`prefill`).
    """
    prompt = jnp.asarray(prompt, jnp.int32)
    if state.cache_impl == "paged":
        assert row_table is not None, "paged install needs allocated pages"
        row_table = jnp.asarray(row_table, jnp.int32)
    else:
        assert prefix_hit is None, "prefix-cache hits require paged KV"
    key = key if key is not None else jax.random.PRNGKey(0)
    if prefix_hit is not None:
        prefix_hit = jnp.asarray(prefix_hit, jnp.int32)
    if true_len is not None:
        true_len = jnp.asarray(true_len, jnp.int32)
    return _install_row_donated(bundle, state, jnp.asarray(row, jnp.int32),
                                prompt, key, row_table,
                                temperature=temperature, ctx_len=ctx_len,
                                prefix_hit=prefix_hit, true_len=true_len,
                                shard_tag=shard_tag)


def _install_rows_impl(bundle, state, rows, prompts, key, row_tables,
                       temperature: float, ctx_len: int, true_len=None,
                       prefix_hits=None, shard_tag=None):
    del shard_tag                       # static cache-splitter (see above)
    k = prompts.shape[0]
    if state.cache_impl == "paged":
        sub = rows_template(state, row_tables)
    else:
        sub = engine_init(bundle, k, state.max_len, ctx_len=ctx_len)
    if prefix_hits is not None:
        # warm batch: every row's shared pages are already spliced into
        # its table row (and COW-copied where needed) by the host; the
        # per-row start vector offsets each suffix independently
        sub = _with_lengths(sub, prefix_hits)
    sub = prefill(bundle, sub, prompts, key=key, temperature=temperature,
                  true_len=true_len, start=prefix_hits)
    # K static adopts: paged pools pass through wholesale (every row's
    # prefill writes already landed in the shared pools), so each adopt
    # is one page-table row patch + small dense-leaf splices
    for i in range(k):
        state = state.adopt_row(rows[i], sub, src_row=i)
    return state


# Donated batched install: one trace per (K, prompt-bucket length,
# warm/cold, state shapes); `rows`, `row_tables`, `true_len` and
# `prefix_hits` are traced.
_install_rows_donated = functools.partial(
    jax.jit, static_argnames=("temperature", "ctx_len", "shard_tag"),
    donate_argnames=("state",))(_install_rows_impl)


def install_rows(bundle, state: EngineState, rows, prompts, key=None,
                 temperature: float = 0.0, row_tables=None,
                 ctx_len: int = 0, true_len=None, prefix_hits=None,
                 shard_tag=None) -> EngineState:
    """Batched serving install: prefill K same-length prompts into K rows
    under ONE donated jit call — the multi-slot analogue of
    :func:`install_row`, collapsing K per-request installs (K dispatches,
    K batch-1 prefills) into one batch-K prefill plus K in-place row
    splices. The async front-end uses it to drain same-length-bucket
    admission groups during the overlap window.

    rows:        [K] slot indices (traced).
    prompts:     [K, P] int32, all padded to one bucket length.
    row_tables:  [K, max_pages] allocated pages per request (paged only).
    true_len:    [K] real prompt lengths under bucket padding.
    prefix_hits: [K] warm-start lengths (paged only): row i's table
        already holds ``prefix_hits[i]`` committed tokens of shared
        prefix-cache pages — ``prompts[i]`` is only its (bucket-padded)
        uncached suffix and ``true_len[i]`` the suffix's real length. The
        host does all per-row COW orchestration BEFORE this call (the
        spliced tables must be write-safe); mixed hit/miss groups are not
        allowed — route misses through the cold path (``prefix_hits``
        absent) so every row shares one warm/cold trace.

    Semantics note: sampling (temperature > 0) draws the K anchors from
    one shared key — not bitwise-identical to K per-request keys — so the
    engine only routes temperature-0 installs here (greedy anchors are
    key-independent, making the batched path token-identical to K single
    installs — warm and cold; asserted by tests/test_frontend.py).
    """
    prompts = jnp.asarray(prompts, jnp.int32)
    rows = jnp.asarray(rows, jnp.int32)
    if state.cache_impl == "paged":
        assert row_tables is not None, "paged install needs allocated pages"
        row_tables = jnp.asarray(row_tables, jnp.int32)
    else:
        assert prefix_hits is None, "prefix-cache hits require paged KV"
    key = key if key is not None else jax.random.PRNGKey(0)
    if true_len is not None:
        true_len = jnp.asarray(true_len, jnp.int32)
    if prefix_hits is not None:
        prefix_hits = jnp.asarray(prefix_hits, jnp.int32)
    return _install_rows_donated(bundle, state, rows, prompts, key,
                                 row_tables, temperature=temperature,
                                 ctx_len=ctx_len, true_len=true_len,
                                 prefix_hits=prefix_hits,
                                 shard_tag=shard_tag)


def prefill_row(bundle, state: EngineState, row, prompt, key=None, ctx=None,
                temperature: float = 0.0, ctx_len: int = 0,
                row_table=None, prefix_hit=None,
                true_len=None) -> EngineState:
    """Prefill a single request into one row of an in-flight state
    (non-donating; ``state`` stays valid — see :func:`install_row` for the
    donated serving path).

    Dense: allocates a batch-1 state with the same ``max_len``, runs the
    normal prefill over ``prompt`` [P], and splices the result into
    ``row`` via :meth:`EngineState.adopt_row`. Paged: prefills through a
    pool-sharing :func:`row_template`; ``row_table`` defaults to the
    identity layout's pages for ``row`` (requires a concrete ``row``).
    """
    prompt = jnp.asarray(prompt, jnp.int32)
    if state.cache_impl == "paged" and row_table is None:
        mp = state.max_pages
        row_table = int(row) * mp + jnp.arange(mp, dtype=jnp.int32)
    if ctx is None:
        return _install_impl(bundle, state, row, prompt,
                             key if key is not None else jax.random.PRNGKey(0),
                             row_table, temperature, ctx_len,
                             prefix_hit=prefix_hit, true_len=true_len)
    # cross-attention contexts stay on the eager path (ctx shapes vary);
    # warm starts / bucketed padding are not plumbed through it
    assert prefix_hit is None and true_len is None, \
        "prefix_hit / true_len are not supported with a cross-attention ctx"
    sub = (row_template(state, row_table)
           if state.cache_impl == "paged"
           else engine_init(bundle, 1, state.max_len, ctx_len=ctx_len))
    sub = prefill(bundle, sub, prompt[None, :], key=key, ctx=ctx,
                  temperature=temperature)
    return state.adopt_row(row, sub)


# ------------------------------------------------------- install accounting -
def _row_nbytes(a, ax) -> int:
    if not hasattr(a, "ndim") or a.ndim == 0 or ax >= a.ndim:
        return 0
    return a.nbytes // a.shape[ax]


def refill_copy_bytes(state: EngineState, n_tokens: int) -> int:
    """Bytes one slot install writes into the wave state (accounting model
    for ``BENCH_serving.json``).

    Dense: ``adopt_row`` rewrites a full row of every cache — max_len
    positions of target KV and drafter features regardless of the prompt
    length. Paged: only the ``n_tokens`` prompt positions land in the
    pools (tail-page writes) plus one page-table row and the small dense
    leaves (window-capped local KV, recurrent states, scalars) — page-size
    order, which is the acceptance criterion for copy-free refill.
    """
    def block_bytes(d, axis_for) -> int:
        total = 0
        paged = kvc.is_paged(d)
        for name, v in d.items():
            if paged and name in ("k", "v"):
                lead = int(np.prod(v.shape[:-4], dtype=np.int64))
                h, dh = v.shape[-2], v.shape[-1]
                total += int(n_tokens) * lead * h * dh * v.dtype.itemsize
            elif name == "pt":
                total += _row_nbytes(v, v.ndim - 2)
            else:
                ax = axis_for(name)
                total += sum(_row_nbytes(a, ax)
                             for a in jax.tree.leaves(v))
        return total

    total = 0
    for name, v in state.target.items():
        if isinstance(v, dict):
            total += block_bytes(v, lambda _n, a=lm.state_batch_axis(name): a)
        else:
            total += _row_nbytes(v, 0)
    total += block_bytes(state.d1_feat, _feat_axis)
    total += block_bytes(state.d2_feat, _feat_axis)
    total += _row_nbytes(state.anchor, 0) + _row_nbytes(state.active, 0)
    return total
