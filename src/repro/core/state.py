"""Typed decode-engine state.

:class:`EngineState` is the single pytree that flows through the decode
loop — target model states (KV caches / recurrent states), the two
drafter feature caches, and the anchor token of the next block. It is
frozen and pytree-registered, so it jits, donates, and crosses a
``jax.lax.while_loop`` boundary unchanged; every cycle produces a *new*
EngineState via :meth:`replace`.

Field shapes are allocated once per request wave by :func:`engine_init`
(static ``batch`` / ``max_len``), which is what lets the whole generation
loop run on device without host round-trips.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core import drafter as dr
from repro.models import lm


@dataclasses.dataclass(frozen=True)
class EngineState:
    """Per-wave decode state (all leaves batched on axis 0 or equivalent).

    target:  ``lm.init_states`` dict — per-layer KV caches / recurrent
             states plus per-example committed ``length`` [B].
    d1_feat: first-drafter feature cache (``drafter.init_feat_cache``).
    d2_feat: second-drafter feature cache.
    anchor:  [B] int32 — the bonus token that roots the next draft block.
    active:  [B] bool — rows still generating. Inactive rows draft a
             degenerate root-only tree, commit zero tokens, and skip every
             KV / feature-cache write, so a finished (or idle) row costs
             no state mutation inside the decode loop and its slot can be
             re-prefilled in place via :meth:`adopt_row`.
    """
    target: Dict[str, Any]
    d1_feat: Dict[str, Any]
    d2_feat: Dict[str, Any]
    anchor: jnp.ndarray
    active: jnp.ndarray

    @property
    def length(self) -> jnp.ndarray:
        """[B] number of committed target positions."""
        return self.target["length"]

    @property
    def batch(self) -> int:
        return self.anchor.shape[0]

    @property
    def max_len(self) -> int:
        """Static cache capacity this state was allocated with."""
        return self.d1_feat["k"].shape[2]

    def replace(self, **kw) -> "EngineState":
        return dataclasses.replace(self, **kw)

    def adopt_row(self, row, other: "EngineState",
                  src_row: int = 0) -> "EngineState":
        """Splice ``other``'s ``src_row`` into this state's ``row``.

        This is the slot-refill primitive: a retired request's row is
        overwritten with a freshly prefilled single-request state (same
        ``max_len``), leaving every other row untouched. ``row`` may be a
        traced index; ``other`` is typically batch-1.
        """
        # feature caches: "length" is batch-leading, k/v are [L, B, T, H, D]
        f_ax = lambda name: 0 if name == "length" else 1      # noqa: E731
        return EngineState(
            target=_adopt_dict(self.target, other.target, row, src_row,
                               lm.state_batch_axis),
            d1_feat=_adopt_dict(self.d1_feat, other.d1_feat, row, src_row,
                                f_ax),
            d2_feat=_adopt_dict(self.d2_feat, other.d2_feat, row, src_row,
                                f_ax),
            anchor=_splice_row(self.anchor, other.anchor, row, src_row, 0),
            active=_splice_row(self.active, other.active, row, src_row, 0),
        )


jax.tree_util.register_pytree_node(
    EngineState,
    lambda s: ((s.target, s.d1_feat, s.d2_feat, s.anchor, s.active), None),
    lambda _, ch: EngineState(*ch),
)


def _splice_row(dst, src, row, src_row, axis):
    """Write src[..., src_row, ...] into dst at ``row`` along ``axis``."""
    if not hasattr(dst, "ndim") or dst.ndim == 0:
        return dst
    sl = jax.lax.index_in_dim(src, src_row, axis, keepdims=False)
    return jax.lax.dynamic_update_index_in_dim(
        dst, sl.astype(dst.dtype), row, axis)


def _adopt_dict(dst, src, row, src_row, axis_for):
    out = {}
    for name, v in dst.items():
        ax = axis_for(name)
        out[name] = jax.tree.map(
            lambda d, s, a=ax: _splice_row(d, s, row, src_row, a),
            v, src[name])
    return out


def engine_init(bundle, batch: int, max_len: int,
                ctx_len: int = 0) -> EngineState:
    """Allocate caches for a request wave (``bundle``: pipeline.SpecBundle)."""
    tcfg = bundle.target_cfg
    dt = jnp.dtype(tcfg.dtype)
    return EngineState(
        target=lm.init_states(tcfg, batch, max_len, ctx_len=ctx_len,
                              dtype=dt),
        d1_feat=dr.init_feat_cache(bundle.d1_cfg, batch, max_len,
                                   dtype=jnp.dtype(bundle.d1_cfg.dtype)),
        d2_feat=dr.init_feat_cache(bundle.d2_cfg, batch, max_len,
                                   dtype=jnp.dtype(bundle.d2_cfg.dtype)),
        anchor=jnp.zeros((batch,), jnp.int32),
        active=jnp.ones((batch,), bool),
    )


def prefill(bundle, state: EngineState, prompts, key=None, ctx=None,
            temperature: float = 0.0) -> EngineState:
    """Process prompts [B, P]; sets anchor = first generated token.

    cache_len is passed as a SCALAR 0: prefill always starts at offset 0, so
    the KV write lowers to dynamic-update-slice (partitionable along the
    kv_seq axis with zero communication) instead of a gather-scatter
    (§Perf: this was 2x9.6GB/layer of all-gather on 32k prefill).
    """
    out = lm.forward(bundle.target_params, prompts, bundle.target_cfg,
                     states=state.target, cache_len=jnp.zeros((), jnp.int32),
                     write_kv=True, ctx=ctx, want_features=True, remat=False)
    b, p = prompts.shape
    positions = jnp.broadcast_to(jnp.arange(p)[None], (b, p))
    d1_feat = dr.extend_feat_cache(
        bundle.d1_params, bundle.d1_cfg, state.d1_feat, out["features"],
        positions, jnp.full((b,), p))
    d2_feat = dr.extend_feat_cache(
        bundle.d2_params, bundle.d2_cfg, state.d2_feat, out["features"],
        positions, jnp.full((b,), p))
    last = out["logits"][:, -1].astype(jnp.float32)
    if temperature > 0:
        anchor = jax.random.categorical(key, last / temperature)
    else:
        anchor = jnp.argmax(last, axis=-1)
    return state.replace(target=out["states"], d1_feat=d1_feat,
                         d2_feat=d2_feat,
                         anchor=anchor.astype(jnp.int32))


def prefill_row(bundle, state: EngineState, row, prompt, key=None, ctx=None,
                temperature: float = 0.0, ctx_len: int = 0) -> EngineState:
    """Prefill a single request into one row of an in-flight state.

    Allocates a batch-1 state with the same ``max_len``, runs the normal
    prefill over ``prompt`` [P], and splices the result into ``row`` via
    :meth:`EngineState.adopt_row`. Other rows' caches, lengths, and anchors
    are untouched, so a serving engine can retire a finished request and
    re-use its slot without re-prefilling the rest of the wave.
    """
    prompt = jnp.asarray(prompt, jnp.int32)
    sub = engine_init(bundle, 1, state.max_len, ctx_len=ctx_len)
    sub = prefill(bundle, sub, prompt[None, :], key=key, ctx=ctx,
                  temperature=temperature)
    return state.adopt_row(row, sub)
