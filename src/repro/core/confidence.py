"""Rejection-boundary estimation from drafter confidence (paper §3.1-3.2).

Eq. 3: c_k = max_v p_k(v)                       (per-position confidence)
Eq. 4: r(i) = prod_{k<=i} c_k * (1 - c_{i+1})    (boundary posterior)
Eq. 5: S = TopK_i r(i)                           (branch fork points)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def confidences(draft_logits, draft_tokens=None):
    """Eq. 3. draft_logits: [..., G, V] over the G drafted positions.

    If ``draft_tokens`` is given (sampled drafts), confidence is the
    probability of the *chosen* token, else the max-probability (argmax).
    """
    probs = jax.nn.softmax(draft_logits.astype(jnp.float32), axis=-1)
    if draft_tokens is None:
        return probs.max(axis=-1)
    return jnp.take_along_axis(probs, draft_tokens[..., None], axis=-1)[..., 0]


def boundary_posterior(conf):
    """Eq. 4. conf: [..., G] confidences of drafted positions 1..G.

    Returns r: [..., G] where r[i] = P(exactly the first i drafted tokens are
    accepted) for i = 0..G-1:
        r[i] = prod_{k<i} c_k * (1 - c_i)
    (the paper's indexing: i tokens accepted, position i+1 rejected).
    The event "all G accepted" carries the leftover mass; it needs no branch.
    """
    cf = conf.astype(jnp.float32)
    prefix = jnp.cumprod(cf, axis=-1)
    prefix_excl = prefix / jnp.maximum(cf, 1e-30)       # prod_{k<i}
    return prefix_excl * (1.0 - cf)


def topk_prefixes(r, k: int):
    """Eq. 5. r: [..., G] -> (scores [..., K], idx [..., K]).

    idx[j] = prefix length i of the j-th branch (fork after i draft tokens).
    """
    return jax.lax.top_k(r, k)


def select_branches(draft_logits, k: int, draft_tokens=None):
    """Full §3.1-3.2: logits -> (conf, r, fork_idx [..., K])."""
    conf = confidences(draft_logits, draft_tokens)
    r = boundary_posterior(conf)
    _, idx = topk_prefixes(r, k)
    return conf, r, idx
