"""Verification: acceptance rules + pluggable target-side verify backends.

Acceptance rules
----------------
Greedy (T=0): node n is ok iff argmax(target logits at parent(n)) == token(n);
acceptance propagates along ancestors; commit the deepest accepted node's
path; bonus = target argmax at that node. This makes D2SD output *exactly*
equal to pure greedy target decoding (property-tested).

Sampling (T>0): SpecInfer-style recursive rejection sampling across sibling
branches. At the frontier node we hold the target residual distribution p;
children are tried in order: accept child c (token x, drafter dist q_c) with
prob min(1, p(x)/q_c(x)); on rejection p <- normalize(max(p - q_c, 0)).
If no child is accepted the bonus is sampled from the final residual. The
committed-token distribution equals the target's exactly (lossless) whenever
sibling tokens were drawn independently from their q_c's.

Backends
--------
A :class:`VerifierBackend` runs the target model over a candidate tree and
commits the accepted path. Two implementations exist, selected from the
target :class:`~repro.config.base.ModelConfig` by :func:`select_backend`:

* :class:`TreeAttentionVerifier` — one forward over the whole tree with an
  ancestor attention mask, then a KV gather-commit. Requires every layer to
  be maskable attention (no recurrent/rwkv blocks).
* :class:`StateReplayVerifier` — DESIGN §5.1: enumerate root-to-leaf rows,
  fold them into the batch axis for a read-only forward, then replay the
  accepted path with ``snap_at`` to advance recurrent states exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import tree as tree_lib
from repro.core.tree import (Tree, best_path, children_table,
                             propagate_acceptance)
from repro.models import kvcache as kvc
from repro.models import lm


def greedy_verify(tree: Tree, target_logits):
    """target_logits: [B, N, V] at every tree node.

    Returns dict(best [B], n_acc [B], path [B, D+1], bonus [B],
    accepted [B,N], ok [B,N]).
    """
    b, n, v = target_logits.shape
    pred = jnp.argmax(target_logits, axis=-1)                 # [B, N]
    parent_c = jnp.clip(tree.parent, 0, n - 1)
    pred_at_parent = jnp.take_along_axis(pred, parent_c, axis=1)
    ok = (pred_at_parent == tree.tokens) & tree.valid
    accepted = propagate_acceptance(tree, ok)
    best, n_acc, path = best_path(tree, accepted)
    bonus = jnp.take_along_axis(pred, best[:, None], axis=1)[:, 0]
    return {"best": best, "n_acc": n_acc, "path": path, "bonus": bonus,
            "accepted": accepted, "ok": ok}


def sampling_verify(tree: Tree, target_logits, draft_probs, key,
                    max_children: int, temperature: float = 1.0):
    """Lossless multi-branch speculative sampling.

    draft_probs: [B, N, V] — the categorical q_n each node's token was drawn
        from (root row ignored). Deterministic (argmax) drafts use a one-hot
        q (valid: point-mass proposal).
    Returns the same dict as greedy_verify (bonus sampled, not argmax).
    """
    b, n, v = target_logits.shape
    kids = children_table(tree, max_children)                # [B, N, C]
    p_target = jax.nn.softmax(
        target_logits.astype(jnp.float32) / max(temperature, 1e-6), axis=-1)

    d = tree.max_depth
    keys = jax.random.split(key, d * max_children + 1)

    def node_gather(arr, idx):
        """arr [B,N,V] or [B,N], idx [B] -> [B,V] or [B]."""
        if arr.ndim == 3:
            return jnp.take_along_axis(arr, idx[:, None, None], axis=1)[:, 0]
        return jnp.take_along_axis(arr, idx[:, None], axis=1)[:, 0]

    cur = jnp.zeros((b,), jnp.int32)          # frontier node (accepted)
    alive = jnp.ones((b,), bool)
    n_acc = jnp.zeros((b,), jnp.int32)
    p_res = node_gather(p_target, cur)        # residual target dist [B,V]
    chosen_path = [cur]
    accepted_nodes = jnp.zeros((b, n), bool).at[:, 0].set(True)

    ki = 0
    for _ in range(d):
        nxt = cur
        took = jnp.zeros((b,), bool)
        for c in range(max_children):
            child = jnp.take_along_axis(
                kids[:, :, c], jnp.clip(cur, 0, n - 1)[:, None], 1)[:, 0]
            has = (child >= 0) & alive & (~took)
            child_s = jnp.clip(child, 0, n - 1)
            tok = node_gather(tree.tokens, child_s)
            qc = node_gather(draft_probs, child_s)            # [B,V]
            px = jnp.take_along_axis(p_res, tok[:, None], 1)[:, 0]
            qx = jnp.take_along_axis(qc, tok[:, None], 1)[:, 0]
            u = jax.random.uniform(keys[ki], (b,)); ki += 1
            accept = has & (u <= px / jnp.maximum(qx, 1e-30))
            nxt = jnp.where(accept, child_s, nxt)
            took = took | accept
            rejected = has & (~accept)
            p_new = jnp.maximum(p_res - qc, 0.0)
            p_new = p_new / jnp.maximum(p_new.sum(-1, keepdims=True), 1e-30)
            p_res = jnp.where(rejected[:, None], p_new, p_res)
        moved = took
        p_res = jnp.where(moved[:, None], node_gather(p_target, nxt), p_res)
        n_acc = n_acc + moved.astype(jnp.int32)
        alive = alive & moved
        cur = nxt
        chosen_path.append(cur)
        accepted_nodes = accepted_nodes | (
            jax.nn.one_hot(cur, n, dtype=bool) & moved[:, None])

    bonus = jax.random.categorical(keys[ki],
                                   jnp.log(jnp.maximum(p_res, 1e-30)))
    path = jnp.stack(chosen_path, axis=1)                     # [B, D+1]
    return {"best": cur, "n_acc": n_acc, "path": path, "bonus": bonus,
            "accepted": accepted_nodes, "ok": accepted_nodes}


# ------------------------------------------------------------- backends ----
@dataclasses.dataclass(frozen=True)
class VerifyOutcome:
    """Result of one target-side verification pass.

    res:        acceptance dict (best/n_acc/path/bonus/accepted/ok) as
                produced by greedy_verify / sampling_verify.
    target:     advanced target states (committed by exactly n_acc+1 tokens).
    path_feats: [B, D+1, Fd] target features along the accepted path (input
                to the drafter feature-cache extension).
    """
    res: dict
    target: Any
    path_feats: jnp.ndarray


class VerifierBackend:
    """Protocol: run the target over a tree and commit the accepted path.

    Backends are mesh-transparent: under a ``use_sharding`` context with
    a ``kv_seq`` rule, the target forward they invoke routes paged
    decode attention through the ``shard_map`` cascade-verify hook
    (``models/blocks.py`` →
    :func:`~repro.distributed.spdecode.sharded_paged_cache_attend` —
    tree/block KV replicated, per-shard cache stats merged by a float32
    LSE psum), while accept/commit logic here sees only global-shaped
    arrays. Callers jitting a backend must thread
    ``sharding.mesh_tag()`` as a static arg (see ``core/pipeline.py``)
    so sharded and unsharded traces don't collide.

    Backends are also read-path-transparent: with
    ``ModelConfig.attn_impl="pallas"`` the tree-verify forward reads
    paged GLOBAL layers through ``kernels.ops.cascade_attention_paged``
    (page pool + page table handed to the kernel, no per-cycle dense
    ``pool_view`` gather; interpret mode off-TPU) and sliding-window
    ROLLING local layers through the dense cascade kernel over their
    rolling buffers (true-capacity position recovery) — selected
    per-bundle via ``pipeline.with_attn_impl(bundle, impl)``; the config
    field is a jit-static so both variants coexist in one process.
    Recurrent/rwkv blocks have no KV cache and are unaffected. Per-
    request tokens are identical across read paths (asserted by the
    tier-1 ``pallas`` marker tests, single-device and sharded, including
    the local/global hybrid target)."""

    name: str = "?"

    def verify(self, bundle, state, tree: Tree, dprobs, max_children: int,
               key) -> VerifyOutcome:
        raise NotImplementedError


def uses_tree_attention(cfg) -> bool:
    """Tree-masked verification requires a pure-attention target."""
    kinds = set(cfg.pattern_for_depth())
    return not (kinds & {"recurrent", "rwkv"})


def select_backend(cfg) -> VerifierBackend:
    """Pick the verify backend from target-model capabilities."""
    return (TreeAttentionVerifier() if uses_tree_attention(cfg)
            else StateReplayVerifier())


class TreeAttentionVerifier(VerifierBackend):
    """Cascade tree-attention verify + KV gather-commit (attention targets)."""

    name = "tree_attention"

    def verify(self, bundle, state, tree, dprobs, max_children, key):
        tcfg = bundle.target_cfg
        temp = bundle.spec.temperature
        mask = tree_lib.attention_mask(tree)
        positions = tree_lib.positions(tree, state.target["length"])
        vout = lm.forward(bundle.target_params, tree.tokens, tcfg,
                          states=state.target, write_kv=False,
                          extra_mask=mask, positions=positions,
                          want_features=True, remat=False)
        logits = vout["logits"].astype(jnp.float32)
        logits = jnp.where(tree.valid[:, :, None], logits, -1e9)
        if temp > 0:
            res = sampling_verify(tree, logits, dprobs, key,
                                  max_children=max_children,
                                  temperature=temp)
        else:
            res = greedy_verify(tree, logits)
        # commit KV by gathering the accepted path from the verify pass;
        # inactive rows commit nothing (length frozen, no cache writes)
        n_commit = jnp.where(state.active, res["n_acc"] + 1, 0)
        new_target = lm.commit_kv(state.target, vout["kv_outs"], tcfg,
                                  res["path"], n_commit)
        path_feats = jnp.take_along_axis(
            vout["features"], res["path"][..., None], axis=1)
        return VerifyOutcome(res=res, target=new_target,
                             path_feats=path_feats)


class StateReplayVerifier(VerifierBackend):
    """DESIGN §5.1: verification for recurrent (SSM / hybrid) targets.

    Enumerate the root-to-leaf token sequence of every branch (K+1 rows of
    length gamma), run the target once with branches folded into batch and
    per-row causal order (read-only states), pick the best row per example,
    then REPLAY the accepted path with write_kv + snap_at to advance all
    states by exactly n_commit tokens.

    NOTE temp>0: per-row chain rejection sampling would need per-row
    residual bookkeeping; we use greedy acceptance on the sampled drafts
    for SSM targets (approximation documented in DESIGN §5.1); ``dprobs``
    is ignored.
    """

    name = "state_replay"

    def verify(self, bundle, state, tree, dprobs, max_children, key):
        del dprobs, max_children, key
        tcfg = bundle.target_cfg
        g = tree.max_depth + 1
        b, n = tree.tokens.shape
        # enumerate root-to-leaf token rows (comb: trunk + one per branch)
        rows = _paths_to_leaves(tree)                          # [B, R, g]
        r = rows.shape[1]
        row_tokens = jnp.take_along_axis(
            jnp.repeat(tree.tokens, r, axis=0),                # [B*R, N]
            rows.reshape(b * r, g), axis=1)                    # [B*R, g]

        def rep(key_name, a):
            if not hasattr(a, "ndim") or a.ndim == 0:
                return a
            return jnp.repeat(a, r, axis=lm.state_batch_axis(key_name))

        def rep_block(k2, v):
            if kvc.is_paged(v):
                # paged KV: the pool has no batch axis — replicate only
                # the page-table rows (branches share the row's pages for
                # this read-only pass) and any dense leaves
                return {kk: (vv if kk in ("k", "v") else
                             jnp.repeat(vv, r, axis=vv.ndim - 2)
                             if kk == "pt" else rep(k2, vv))
                        for kk, vv in v.items()}
            return jax.tree.map(lambda a: rep(k2, a), v)

        states_rep = {k2: (rep_block(k2, v) if isinstance(v, dict)
                           else rep(k2, v))
                      for k2, v in state.target.items()}
        vout = lm.forward(bundle.target_params, row_tokens, tcfg,
                          states=states_rep, write_kv=False, remat=False)
        logits = vout["logits"].astype(jnp.float32)            # [B*R, g, V]

        pred_full = jnp.argmax(logits, axis=-1)                # [B*R, g]
        ok = (pred_full[:, :-1] == row_tokens[:, 1:])
        # padded path entries repeat the leaf node; mask beyond leaf depth
        depth_leaf = jnp.take_along_axis(
            tree.depth, rows.reshape(b, r, g)[:, :, -1], axis=1)   # [B,R]
        ok = ok & (jnp.arange(g - 1)[None, :] <
                   depth_leaf.reshape(b * r)[:, None])
        n_acc_r = (jnp.cumprod(ok.astype(jnp.int32), axis=1)
                   .sum(1).reshape(b, r))
        best_row = jnp.argmax(n_acc_r, axis=1)
        n_acc = jnp.take_along_axis(n_acc_r, best_row[:, None], 1)[:, 0]
        path = jnp.take_along_axis(
            rows, best_row[:, None, None].repeat(g, 2), axis=1)[:, 0]
        pred_best = jnp.take_along_axis(
            pred_full.reshape(b, r, g),
            best_row[:, None, None].repeat(g, 2), axis=1)[:, 0]  # [B,g]
        bonus = jnp.take_along_axis(pred_best, n_acc[:, None], axis=1)[:, 0]

        # replay accepted path to advance states by exactly n_commit;
        # inactive rows snap at 0: recurrent states and lengths stay frozen
        n_commit = jnp.where(state.active, n_acc + 1, 0)
        path_tokens = jnp.take_along_axis(tree.tokens, path, axis=1)  # [B,g]
        rout = lm.forward(bundle.target_params, path_tokens, tcfg,
                          states=state.target, write_kv=True,
                          snap_at=n_commit, attend_cache_on_write=True,
                          want_features=True, want_logits=False, remat=False)
        res = {"best": jnp.take_along_axis(path, n_acc[:, None], 1)[:, 0],
               "n_acc": n_acc, "path": path,
               "bonus": bonus.astype(jnp.int32),
               "accepted": None, "ok": None}
        return VerifyOutcome(res=res, target=rout["states"],
                             path_feats=rout["features"])


def _paths_to_leaves(tree: Tree):
    """[B, R, g] node-index rows, one per leaf (trunk + each branch).

    Rows are recovered via parent walks from the deepest node of each branch
    segment; static for the comb/chain layouts produced by the built-in
    strategies.
    """
    b, n = tree.tokens.shape
    g = tree.max_depth + 1
    # leaf candidates: trunk leaf = node g-1 ; branch leaves = last valid
    # node of each (g-1)-sized branch segment. For chain trees n == g (+0).
    if n == g:                                     # chain
        leaves = jnp.broadcast_to(jnp.arange(1) + (n - 1), (b, 1))
    else:
        k = (n - g) // (g - 1)
        seg_last = []
        for s in range(k):
            start = g + s * (g - 1)
            seg = jnp.arange(start, start + g - 1)
            validity = tree.valid[:, seg]
            # last valid node in segment (fork at g-2 -> single node)
            last_off = jnp.maximum(validity.sum(1) - 1, 0)
            seg_last.append(start + last_off)
        leaves = jnp.stack([jnp.full((b,), g - 1)] + seg_last, axis=1)
    rws = []
    cur = leaves
    rws.append(cur)
    for _ in range(g - 1):
        cur = jnp.maximum(
            jnp.take_along_axis(tree.parent, cur, axis=1), 0)
        rws.append(cur)
    up = jnp.stack(rws, axis=2)                    # [B, R, g] leaf->root
    depth_leaf = jnp.take_along_axis(tree.depth, leaves, axis=1)  # [B,R]
    d_idx = jnp.arange(g)[None, None, :]
    take = jnp.clip(depth_leaf[:, :, None] - d_idx, 0, g - 1)
    path = jnp.take_along_axis(up, take, axis=2)
    # pad beyond leaf depth with the leaf itself (token garbage but the
    # acceptance count never exceeds leaf depth because pred!=token there
    # cannot extend past the leaf — we additionally clamp below)
    path = jnp.where(d_idx <= depth_leaf[:, :, None], path,
                     leaves[:, :, None])
    return path


def chain_prefix_accept_greedy(tokens, target_logits):
    """Sequential prefix acceptance for branch-batched (SSM) verification.

    tokens: [B, T] candidate tokens t_1..t_T whose parents are the previous
        positions (t_0 = anchor handled by caller: logits[:, i] predicts
        tokens[:, i]).
    target_logits: [B, T, V] logits at [anchor, t_1..t_{T-1}].
    Returns (n_acc [B], pred [B, T]).
    """
    pred = jnp.argmax(target_logits, axis=-1)
    ok = pred == tokens
    acc_prefix = jnp.cumprod(ok.astype(jnp.int32), axis=1)
    return acc_prefix.sum(axis=1), pred
