"""Verification: greedy longest-prefix and lossless multi-branch sampling.

Greedy (T=0): node n is ok iff argmax(target logits at parent(n)) == token(n);
acceptance propagates along ancestors; commit the deepest accepted node's
path; bonus = target argmax at that node. This makes D2SD output *exactly*
equal to pure greedy target decoding (property-tested).

Sampling (T>0): SpecInfer-style recursive rejection sampling across sibling
branches. At the frontier node we hold the target residual distribution p;
children are tried in order: accept child c (token x, drafter dist q_c) with
prob min(1, p(x)/q_c(x)); on rejection p <- normalize(max(p - q_c, 0)).
If no child is accepted the bonus is sampled from the final residual. The
committed-token distribution equals the target's exactly (lossless) whenever
sibling tokens were drawn independently from their q_c's.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.tree import (Tree, best_path, children_table,
                             propagate_acceptance)


def greedy_verify(tree: Tree, target_logits):
    """target_logits: [B, N, V] at every tree node.

    Returns dict(best [B], n_acc [B], path [B, D+1], bonus [B],
    accepted [B,N], ok [B,N]).
    """
    b, n, v = target_logits.shape
    pred = jnp.argmax(target_logits, axis=-1)                 # [B, N]
    parent_c = jnp.clip(tree.parent, 0, n - 1)
    pred_at_parent = jnp.take_along_axis(pred, parent_c, axis=1)
    ok = (pred_at_parent == tree.tokens) & tree.valid
    accepted = propagate_acceptance(tree, ok)
    best, n_acc, path = best_path(tree, accepted)
    bonus = jnp.take_along_axis(pred, best[:, None], axis=1)[:, 0]
    return {"best": best, "n_acc": n_acc, "path": path, "bonus": bonus,
            "accepted": accepted, "ok": ok}


def sampling_verify(tree: Tree, target_logits, draft_probs, key,
                    max_children: int, temperature: float = 1.0):
    """Lossless multi-branch speculative sampling.

    draft_probs: [B, N, V] — the categorical q_n each node's token was drawn
        from (root row ignored). Deterministic (argmax) drafts use a one-hot
        q (valid: point-mass proposal).
    Returns the same dict as greedy_verify (bonus sampled, not argmax).
    """
    b, n, v = target_logits.shape
    kids = children_table(tree, max_children)                # [B, N, C]
    p_target = jax.nn.softmax(
        target_logits.astype(jnp.float32) / max(temperature, 1e-6), axis=-1)

    d = tree.max_depth
    keys = jax.random.split(key, d * max_children + 1)

    def node_gather(arr, idx):
        """arr [B,N,V] or [B,N], idx [B] -> [B,V] or [B]."""
        if arr.ndim == 3:
            return jnp.take_along_axis(arr, idx[:, None, None], axis=1)[:, 0]
        return jnp.take_along_axis(arr, idx[:, None], axis=1)[:, 0]

    cur = jnp.zeros((b,), jnp.int32)          # frontier node (accepted)
    alive = jnp.ones((b,), bool)
    n_acc = jnp.zeros((b,), jnp.int32)
    p_res = node_gather(p_target, cur)        # residual target dist [B,V]
    chosen_path = [cur]
    accepted_nodes = jnp.zeros((b, n), bool).at[:, 0].set(True)

    ki = 0
    for _ in range(d):
        nxt = cur
        took = jnp.zeros((b,), bool)
        for c in range(max_children):
            child = jnp.take_along_axis(
                kids[:, :, c], jnp.clip(cur, 0, n - 1)[:, None], 1)[:, 0]
            has = (child >= 0) & alive & (~took)
            child_s = jnp.clip(child, 0, n - 1)
            tok = node_gather(tree.tokens, child_s)
            qc = node_gather(draft_probs, child_s)            # [B,V]
            px = jnp.take_along_axis(p_res, tok[:, None], 1)[:, 0]
            qx = jnp.take_along_axis(qc, tok[:, None], 1)[:, 0]
            u = jax.random.uniform(keys[ki], (b,)); ki += 1
            accept = has & (u <= px / jnp.maximum(qx, 1e-30))
            nxt = jnp.where(accept, child_s, nxt)
            took = took | accept
            rejected = has & (~accept)
            p_new = jnp.maximum(p_res - qc, 0.0)
            p_new = p_new / jnp.maximum(p_new.sum(-1, keepdims=True), 1e-30)
            p_res = jnp.where(rejected[:, None], p_new, p_res)
        moved = took
        p_res = jnp.where(moved[:, None], node_gather(p_target, nxt), p_res)
        n_acc = n_acc + moved.astype(jnp.int32)
        alive = alive & moved
        cur = nxt
        chosen_path.append(cur)
        accepted_nodes = accepted_nodes | (
            jax.nn.one_hot(cur, n, dtype=bool) & moved[:, None])

    bonus = jax.random.categorical(keys[ki],
                                   jnp.log(jnp.maximum(p_res, 1e-30)))
    path = jnp.stack(chosen_path, axis=1)                     # [B, D+1]
    return {"best": cur, "n_acc": n_acc, "path": path, "bonus": bonus,
            "accepted": accepted_nodes, "ok": accepted_nodes}


def chain_prefix_accept_greedy(tokens, target_logits):
    """Sequential prefix acceptance for branch-batched (SSM) verification.

    tokens: [B, T] candidate tokens t_1..t_T whose parents are the previous
        positions (t_0 = anchor handled by caller: logits[:, i] predicts
        tokens[:, i]).
    target_logits: [B, T, V] logits at [anchor, t_1..t_{T-1}].
    Returns (n_acc [B], pred [B, T]).
    """
    pred = jnp.argmax(target_logits, axis=-1)
    ok = pred == tokens
    acc_prefix = jnp.cumprod(ok.astype(jnp.int32), axis=1)
    return acc_prefix.sum(axis=1), pred
