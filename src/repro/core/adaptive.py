"""Beyond-paper: adaptive branch budgeting from the boundary posterior.

The paper fixes K per deployment and observes (§4.2) that gains shrink on
open-ended chat "where the boundary posterior r(i) is more diffuse". That
observation inverts into a scheduler: spend second-draft branches only where
they pay.

  * If r(i) is CONCENTRATED (low entropy), one or two branches capture most
    of the recovery mass — extra branches verify tokens that are already
    dead.
  * If r(i) is DIFFUSE (high entropy), more branches each carry real mass.
  * If the all-accept probability prod(c) dominates, the first draft will
    likely survive whole — skip the second draft entirely (saves a full
    VP pass + (K)(gamma-1) verify tokens).

``choose_k`` maps the posterior to a per-wave branch count inside a fixed
[k_min, k_max] budget using posterior coverage: the smallest K whose top-K
mass exceeds ``coverage`` of the total rejection mass. Pure function -> unit
tested; the engine applies the wave-max so tree topology stays static per
cycle (jit-friendly).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def posterior_coverage_k(r, coverage: float = 0.85, k_max: int = 4):
    """Smallest K with top-K posterior mass >= coverage * total mass. [B]."""
    total = jnp.maximum(r.sum(-1, keepdims=True), 1e-9)
    top = jax.lax.top_k(r, min(k_max, r.shape[-1]))[0]
    cum = jnp.cumsum(top / total, axis=-1)
    need = (cum < coverage).sum(-1) + 1
    return jnp.minimum(need, k_max).astype(jnp.int32)


def skip_second_draft(conf, threshold: float = 0.7):
    """True where P(whole first draft accepted) = prod(c_k) >= threshold:
    the VP pass is unlikely to add tokens. [B] bool."""
    return jnp.prod(conf.astype(jnp.float32), axis=-1) >= threshold


def choose_k(conf, r, *, coverage: float = 0.85, k_max: int = 4,
             skip_threshold: float = 0.7):
    """Per-example branch budget; 0 = skip the second draft.

    Returns [B] int32 in {0, 1, .., k_max}. The engine takes max over the
    wave (static topology per compiled cycle) and can bucket waves by K for
    multi-program serving.
    """
    k = posterior_coverage_k(r, coverage, k_max)
    return jnp.where(skip_second_draft(conf, skip_threshold), 0, k)


def expected_recovery(r, fork_idx, gamma: int):
    """E[extra accepted tokens | branch at fork i succeeds to depth d] upper
    bound: sum_i r(i) * (gamma - 1 - i) over the selected forks — the napkin
    value-of-branching used to tune coverage offline."""
    g1 = r.shape[-1]
    sel = jnp.take_along_axis(r, fork_idx, axis=-1)
    remaining = (g1 - fork_idx).astype(jnp.float32)
    return (sel * remaining).sum(-1)
