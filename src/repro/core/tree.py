"""Candidate prefix trees for joint verification (paper §3.2-3.3).

A tree is a static-shape node table (size N) with *per-example* traced parent
pointers, so one implementation covers the comb-shaped D2SD tree, naive-K
resample trees, third-level trees (forks on branches), and single chains.
Node 0 is always the anchor (root). Invalid (padding) nodes carry
valid=False and parent pointing at themselves.

All fields are batched [B, N]; masks/paths use O(depth) gather iterations —
no python loops over traced values.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Tree:
    tokens: jnp.ndarray      # [B, N] int32
    parent: jnp.ndarray      # [B, N] int32 (parent[0] = -1)
    depth: jnp.ndarray       # [B, N] int32 (root depth 0)
    valid: jnp.ndarray       # [B, N] bool
    max_depth: int           # static bound on depth

    @property
    def n(self) -> int:
        return self.parent.shape[-1]

    @property
    def b(self) -> int:
        return self.parent.shape[0]


jax.tree_util.register_pytree_node(
    Tree,
    lambda t: ((t.tokens, t.parent, t.depth, t.valid), t.max_depth),
    lambda aux, ch: Tree(*ch, max_depth=aux),
)


def _gather(arr, idx):
    """arr [B,N], idx [B,M] -> [B,M]."""
    return jnp.take_along_axis(arr, idx, axis=1)


def comb_tree(anchor, trunk_tokens, branch_tokens, fork_idx, gamma: int):
    """Build the D2SD comb tree (per-example topology).

    anchor:        [B] anchor token (bonus from previous cycle)
    trunk_tokens:  [B, gamma-1] first-draft tokens t_1..t_{gamma-1}
    branch_tokens: [B, K, gamma-1] second-draft tokens for slots 1..gamma-1
                   (branch b uses slots fork_b+1..gamma-1; rest ignored)
    fork_idx:      [B, K] prefix lengths i in 0..gamma-2 (Eq. 5 top-K)

    Node layout (N = gamma + K*(gamma-1)):
      node 0:           anchor (depth 0)
      nodes 1..gamma-1: trunk token i at depth i
      node gamma + b*(gamma-1) + j (j=0..gamma-2): branch b suffix node j,
        slot fork_b+1+j, valid iff slot <= gamma-1.
    """
    b = anchor.shape[0]
    g = gamma
    k = branch_tokens.shape[1]
    n = g + k * (g - 1)

    node = jnp.arange(n)
    trunk_part = node < g
    bidx = jnp.clip((node - g) // (g - 1), 0, max(k - 1, 0))
    j = jnp.clip(node - g - bidx * (g - 1), 0, g - 2)
    fork = fork_idx[:, bidx]                               # [B, N]
    slot = jnp.where(trunk_part[None], node[None], fork + 1 + j[None])
    depth = slot
    valid = jnp.where(trunk_part[None], True, slot <= g - 1)
    # parents: trunk i -> i-1 ; branch j=0 -> trunk node fork ; j>0 -> prev
    parent = jnp.where(
        trunk_part[None], node[None] - 1,
        jnp.where((j == 0)[None], fork, node[None] - 1))
    parent = jnp.where(node[None] == 0, -1, parent)

    slot_c = jnp.clip(slot - 1, 0, g - 2)                  # [B, N]
    trunk_tok = _gather(trunk_tokens, slot_c)
    br_tok = _gather(branch_tokens.reshape(b, -1),
                     bidx[None] * (g - 1) + slot_c)
    tokens = jnp.where(trunk_part[None], trunk_tok, br_tok)
    tokens = jnp.where(node[None] == 0, anchor[:, None], tokens)
    tokens = jnp.where(valid, tokens, 0)
    return Tree(tokens=tokens.astype(jnp.int32),
                parent=jnp.broadcast_to(parent, (b, n)).astype(jnp.int32),
                depth=jnp.broadcast_to(depth, (b, n)).astype(jnp.int32),
                valid=jnp.broadcast_to(valid, (b, n)), max_depth=g - 1)


def extend_third_level(tree: Tree, branch_tokens3, fork_idx, fork3_idx,
                       gamma: int):
    """Table 7: stack a third VP level — one extra branch per second-level
    branch, forked at that branch's own top-1 predicted boundary.

    branch_tokens3: [B, K, gamma-1] third-draft tokens for slots 1..gamma-1
    fork_idx:  [B, K] second-level forks i_b (as in comb_tree)
    fork3_idx: [B, K] third-level fork slots s_b (absolute block slot,
               s_b > i_b); the third branch of b hangs off branch b's node at
               slot s_b and re-drafts slots s_b+1..gamma-1.
    """
    b, k = fork_idx.shape
    g = gamma
    n0 = tree.n
    n3 = k * (g - 1)
    node = jnp.arange(n3)
    bidx = node // (g - 1)
    j = node - bidx * (g - 1)
    s = fork3_idx[:, bidx]                                  # [B, n3]
    slot = s + 1 + j[None]
    valid = slot <= g - 1
    depth = slot
    # parent: j=0 -> branch b's node at slot s (tree node g + b(g-1) + s-i_b-1)
    ib = fork_idx[:, bidx]
    parent_of_head = g + bidx[None] * (g - 1) + (s - ib - 1)
    # if s == i_b (degenerate: fork at branch root) -> parent is trunk node i_b
    parent_of_head = jnp.where(s > ib, parent_of_head, ib)
    parent = jnp.where((j == 0)[None], parent_of_head, n0 + node[None] - 1)

    slot_c = jnp.clip(slot - 1, 0, g - 2)
    toks = _gather(branch_tokens3.reshape(b, -1),
                   bidx[None] * (g - 1) + slot_c)
    toks = jnp.where(valid, toks, 0)

    tokens = jnp.concatenate([tree.tokens, toks.astype(jnp.int32)], axis=1)
    parent_all = jnp.concatenate([tree.parent, parent.astype(jnp.int32)], axis=1)
    depth_all = jnp.concatenate([tree.depth, depth.astype(jnp.int32)], axis=1)
    valid_all = jnp.concatenate([tree.valid, valid], axis=1)
    return Tree(tokens=tokens, parent=parent_all, depth=depth_all,
                valid=valid_all, max_depth=tree.max_depth)


def chain_tree(anchor, tokens):
    """Single chain (DFlash / EAGLE baseline): tokens [B,G]."""
    b, g = tokens.shape
    n = g + 1
    node = jnp.arange(n)
    parent = jnp.broadcast_to(node - 1, (b, n))
    toks = jnp.concatenate([anchor[:, None], tokens], axis=1)
    return Tree(tokens=toks.astype(jnp.int32), parent=parent.astype(jnp.int32),
                depth=jnp.broadcast_to(node, (b, n)).astype(jnp.int32),
                valid=jnp.ones((b, n), bool), max_depth=g)


def ancestor_mask(tree: Tree) -> jnp.ndarray:
    """[B, N, N] bool: M[u, v] = v is ancestor-of-or-equal-to u."""
    b, n = tree.parent.shape
    m = jnp.broadcast_to(jnp.eye(n, dtype=bool), (b, n, n))
    cur = tree.parent                                       # [B, N]
    for _ in range(tree.max_depth):
        hot = jax.nn.one_hot(jnp.clip(cur, 0, n - 1), n, dtype=bool)
        m = m | (hot & (cur >= 0)[..., None])
        cur = jnp.where(cur >= 0, _gather(tree.parent, jnp.clip(cur, 0, n - 1)),
                        -1)
    return m


def attention_mask(tree: Tree) -> jnp.ndarray:
    """Tree attention mask including validity: [B, N, N]."""
    m = ancestor_mask(tree)
    b, n = tree.parent.shape
    return (m & tree.valid[:, None, :] & tree.valid[:, :, None]) | \
        jnp.broadcast_to(jnp.eye(n, dtype=bool), (b, n, n))


def positions(tree: Tree, base) -> jnp.ndarray:
    """Absolute positions for RoPE: base + depth. base: [B] -> [B, N]."""
    return (jnp.asarray(base)[:, None] + tree.depth).astype(jnp.int32)


def children_table(tree: Tree, max_children: int) -> jnp.ndarray:
    """[B, N, C] children per node (-1 padded), sibling order by node id
    (trunk child first for comb trees — greedy tie-break prefers trunk)."""
    b, n = tree.parent.shape
    parent = jnp.where(tree.valid, tree.parent, -2)
    order = jnp.arange(n)
    same = (parent[:, None, :] == parent[:, :, None]) & \
        (order[None, None, :] < order[None, :, None])
    rank = same.sum(axis=2)                                 # [B, N]
    ok = (parent >= 0) & (rank < max_children)
    p_idx = jnp.where(ok, parent, n)
    r_idx = jnp.where(ok, rank, 0)
    tbl = jnp.full((b, n + 1, max_children), -1, jnp.int32)
    bidx = jnp.broadcast_to(jnp.arange(b)[:, None], (b, n))
    tbl = tbl.at[bidx, p_idx, r_idx].set(
        jnp.where(ok, order[None], -1).astype(jnp.int32), mode="drop")
    return tbl[:, :n]


def best_path(tree: Tree, accepted: jnp.ndarray):
    """Longest-accepted-prefix across branches (paper step iv).

    accepted: [B, N] bool. Returns (best [B], n_acc [B], path [B, D+1]) where
    path[d] = node at depth d along the best root-to-leaf walk (padded with
    the leaf beyond n_acc).
    """
    b, n = accepted.shape
    acc = (accepted & tree.valid).at[:, 0].set(True)
    score = jnp.where(acc, tree.depth, -1)
    best = jnp.argmax(score, axis=1)
    n_acc = jnp.take_along_axis(score, best[:, None], axis=1)[:, 0]

    d_max = tree.max_depth
    path_rev = [best]
    cur = best
    for _ in range(d_max):
        cur = jnp.maximum(_gather(tree.parent, cur[:, None])[:, 0], 0)
        path_rev.append(cur)
    path_up = jnp.stack(path_rev, axis=1)             # [B, D+1] leaf->root
    d_idx = jnp.arange(d_max + 1)[None, :]
    take = jnp.clip(n_acc[:, None] - d_idx, 0, d_max)
    path = jnp.take_along_axis(path_up, take, axis=1)
    path = jnp.where(d_idx <= n_acc[:, None], path, best[:, None])
    return best, n_acc, path


def propagate_acceptance(tree: Tree, node_ok: jnp.ndarray) -> jnp.ndarray:
    """accepted[n] = node_ok[n] AND all ancestors ok (root True). [B,N].

    Iterates 2*max_depth+1 times: INVALID padding nodes chain through a
    branch of up to max_depth-1 hops before reaching the fork, so their
    hop distance to the root can reach ~2*max_depth (valid nodes are
    within max_depth). The engine masks invalid nodes anyway; the extra
    iterations make the property hold unconditionally.
    """
    b, n = node_ok.shape
    acc = node_ok.at[:, 0].set(True)
    parent_c = jnp.clip(tree.parent, 0, n - 1)
    for _ in range(2 * tree.max_depth + 1):
        acc = acc & jnp.where(tree.parent >= 0,
                              _gather(acc, parent_c), True)
    return acc
