# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
#
# Public decode-engine API (post strategy/backend redesign):
#   pipeline    — SpecBundle, decode_cycle, generate, generate_ondevice
#   state       — EngineState, engine_init (cache_impl dense|paged),
#                 prefill, install_row (donated slot refill), row_template
#   strategies  — DraftStrategy protocol + registry (register_strategy)
#   verify      — VerifierBackend protocol + select_backend, acceptance rules
#   tree        — candidate prefix trees for joint verification
