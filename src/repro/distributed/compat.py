"""Version-compat shims for jax APIs that moved between releases.

The codebase targets the current explicit-sharding API (``jax.shard_map``
with ``check_vma``, ``jax.make_mesh(..., axis_types=...)``); older jax
(<= 0.4.x) ships ``jax.experimental.shard_map.shard_map`` with the
equivalent ``check_rep`` flag and a ``make_mesh`` without ``axis_types``.
Route every call through here so the rest of the tree stays on the new
spelling.
"""
from __future__ import annotations

import inspect

import jax

_HAS_TOP_SHARD_MAP = hasattr(jax, "shard_map")
_HAS_AXIS_TYPES = (hasattr(jax.sharding, "AxisType")
                   and "axis_types" in
                   inspect.signature(jax.make_mesh).parameters)


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` on new jax; experimental shard_map on old.

    ``check_vma`` (new name) == ``check_rep`` (old name): let shard_map
    prove psum'd outputs replicated so it skips the output all-gather.
    """
    if _HAS_TOP_SHARD_MAP:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


def pvary(x, axes):
    """``jax.lax.pvary`` (mark an array device-varying over manual axes).

    Old jax has no varying-manual-axes tracking — its ``check_rep``
    machinery treats replicated operands as compatible with sharded ones —
    so the shim is the identity there.
    """
    if hasattr(jax.lax, "pvary"):
        return jax.lax.pvary(x, tuple(axes))
    return x


def make_mesh(axis_shapes, axis_names, devices=None):
    """``jax.make_mesh`` with Auto axis_types when the API supports them."""
    kw = {}
    if devices is not None:
        kw["devices"] = devices
    if _HAS_AXIS_TYPES:
        kw["axis_types"] = (jax.sharding.AxisType.Auto,) * len(axis_names)
    return jax.make_mesh(axis_shapes, axis_names, **kw)
