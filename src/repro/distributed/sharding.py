"""Logical-axis sharding: name-based rules mapping logical axes to mesh axes.

Usage::

    rules = LOGICAL_RULES  # or a customized dict
    with use_sharding(mesh, rules):
        y = constrain(y, ("batch", "seq", "embed"))

Outside a ``use_sharding`` context (or without a mesh) ``constrain`` is a
no-op, so model code is mesh-agnostic: smoke tests run on 1 CPU device, the
dry-run runs on 512 host devices, production on real pods.

Parameter shardings are derived from *parameter path names* via
``param_pspec`` — every weight in the model zoo follows the naming scheme
below, so rules are robust without threading metadata through init.
"""
from __future__ import annotations

import contextlib
import re
import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axis (or tuple of mesh axes, or None = replicate)
# "fsdp" entries are only active when the rules enable them.
LOGICAL_RULES: Dict[str, object] = {
    "batch": ("pod", "data"),     # data parallel over pod+data
    "seq": None,                  # activations: sequence replicated by default
    "act_seq": "model",           # sequence-parallel activations between blocks
    "embed": None,                # model dim of activations
    "vocab": "model",             # embedding/lm-head vocab dim
    "embed_fsdp": "data",         # FSDP: shard param embed dim over data
    "heads": "model",             # attention q heads
    "kv_heads": None,             # kv heads often tiny (2-8): replicate, SP the seq
    "kv_seq": "model",            # decode: KV cache sequence sharding
    "ffn": "model",               # MLP hidden
    "experts": "model",           # MoE expert dim
    "expert_ffn": None,           # within-expert ffn (set to None when EP active)
    "stage": "pod",               # pipeline stages (pod_role="pipeline")
}


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Dict[str, object] = dict(LOGICAL_RULES)
        self.fsdp: bool = False


_CTX = _Ctx()


@contextlib.contextmanager
def use_sharding(mesh: Optional[Mesh], rules: Optional[Dict[str, object]] = None,
                 fsdp: bool = False):
    old = (_CTX.mesh, _CTX.rules, _CTX.fsdp)
    _CTX.mesh = mesh
    _CTX.rules = dict(rules) if rules is not None else dict(LOGICAL_RULES)
    _CTX.fsdp = fsdp
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules, _CTX.fsdp = old


def active_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def fsdp_enabled() -> bool:
    return _CTX.fsdp


def _mesh_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)


def logical_to_pspec(logical: Sequence[Optional[str]],
                     mesh: Optional[Mesh] = None,
                     rules: Optional[Dict[str, object]] = None) -> P:
    """Map a tuple of logical axis names to a PartitionSpec for ``mesh``.

    Mesh axes absent from the mesh are dropped (e.g. 'pod' on a 2D mesh).
    """
    mesh = mesh or _CTX.mesh
    rules = rules or _CTX.rules
    avail = set(_mesh_axes(mesh)) if mesh is not None else set()
    out, used = [], set()
    for name in logical:
        axes = rules.get(name) if name is not None else None
        if axes is None:
            out.append(None)
            continue
        if isinstance(axes, str):
            axes = (axes,)
        keep = tuple(a for a in axes if a in avail and a not in used)
        used.update(keep)
        if not keep:
            out.append(None)
        elif len(keep) == 1:
            out.append(keep[0])
        else:
            out.append(keep)
    return P(*out)


def _axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def fit_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop mesh axes from a PartitionSpec where the dim isn't divisible
    (e.g. batch=1 long-context decode on a 512-chip mesh)."""
    sizes = _axis_sizes(mesh)
    out = []
    for d, entry in enumerate(spec):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        keep = []
        prod = 1
        for a in axes:
            if shape[d] % (prod * sizes[a]) == 0:
                keep.append(a)
                prod *= sizes[a]
        out.append(tuple(keep) if len(keep) > 1 else
                   (keep[0] if keep else None))
    return P(*out)


def constrain(x, logical: Sequence[Optional[str]]):
    """with_sharding_constraint by logical names; no-op without a mesh."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    spec = fit_spec(logical_to_pspec(logical, mesh), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def mesh_tag():
    """Hashable fingerprint of the active (mesh, rules, fsdp) context.

    jit caches key on avals, not on this module's threadlocal context; any
    jitted function whose TRACE depends on the active mesh (``constrain``
    calls, the shard_map decode hooks) must take this as a static argument
    so one process can hold sharded and unsharded specializations side by
    side — the sharded-vs-single-device parity tests do exactly that.
    Returns None outside a mesh context.
    """
    mesh = _CTX.mesh
    if mesh is None:
        return None
    rules = tuple(sorted(
        (k, tuple(v) if isinstance(v, (tuple, list)) else v)
        for k, v in _CTX.rules.items()))
    return (tuple(mesh.axis_names), tuple(mesh.devices.shape), rules,
            _CTX.fsdp)


def shard_put(x, logical: Sequence[Optional[str]]):
    """``constrain`` that also works on concrete arrays (eager placement).

    Inside a trace this is ``with_sharding_constraint``; on a concrete
    array it is a ``device_put`` onto the fitted NamedSharding — the eager
    half of the borrowed-pool contract (``ServingEngine`` allocates its
    engine-lifetime pool outside any trace). No-op without a mesh.
    """
    mesh = _CTX.mesh
    if mesh is None:
        return x
    if isinstance(x, jax.core.Tracer):
        return constrain(x, logical)
    spec = fit_spec(logical_to_pspec(logical, mesh), x.shape, mesh)
    return jax.device_put(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Parameter sharding by path name.
#
# Naming convention (suffix of the '/'-joined path):
#   embedding            -> (vocab, embed*)
#   lm_head              -> (embed*, vocab)
#   wq / wkv-ish:
#     attn/wq            -> (embed*, heads)     [d, Hq*Dh fused]
#     attn/wk, attn/wv   -> (embed*, kv_heads)
#     attn/wo            -> (heads, embed*)
#     *bias* 1-d         -> replicated
#   mlp/w_in, mlp/w_gate -> (embed*, ffn)
#   mlp/w_out            -> (ffn, embed*)
#   moe/w_in|w_gate      -> (experts, embed, ffn)
#   moe/w_out            -> (experts, ffn, embed)
#   moe/router           -> (embed, experts-as-ffn? keep replicated cols)
#   scale / norm 1-d     -> replicated
# Scanned stacks have a leading layer axis -> None prepended.
# embed* becomes "embed_fsdp" when FSDP is on (params only).
# ---------------------------------------------------------------------------

_PARAM_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    # --- decoding state (caches) ---
    (r"(^|/)(k|v)$", ("batch", "kv_seq", "kv_heads_p", None)),
    (r"(^|/)(ck|cv)$", ("batch", None, "kv_heads_p", None)),
    (r"tm_s$", ("batch", "heads", None, None)),
    (r"(rg_h)$", ("batch", "ffn")),
    (r"(conv_buf)$", ("batch", None, "ffn")),
    (r"(tm_x_prev|cm_x_prev)$", ("batch", None)),
    (r"length$", ("batch",)),
    # --- RWKV time/channel mix (before generic wk/wv rules) ---
    (r"rwkv_tm/(wr|wk|wv|wg)$", ("p_embed", "heads")),
    (r"rwkv_tm/wo$", ("heads", "p_embed")),
    (r"rwkv_tm/w_lora_b$", (None, "heads")),
    (r"rwkv_cm/wk$", ("p_embed", "ffn")),
    (r"rwkv_cm/wv$", ("ffn", "p_embed")),
    (r"rwkv_cm/wr$", ("p_embed", "p_embed")),
    # --- RG-LRU ---
    (r"(w_branch)$", ("p_embed", "ffn")),
    (r"(wa|wx)$", ("p_embed", "ffn")),
    (r"conv_w$", (None, "ffn")),
    # --- embeddings / heads ---
    (r"embedding$", ("vocab", "p_embed")),
    (r"lm_head$", ("p_embed", "vocab")),
    (r"head$", ("p_embed", "vocab")),
    # --- attention / MLP ---
    (r"(wq|wqkv)$", ("p_embed", "heads")),
    (r"(wk|wv)$", ("p_embed", "kv_heads_p")),
    (r"wo$", ("heads", "p_embed")),
    (r"(w_in|w_gate|w_up)$", ("p_embed", "ffn")),
    (r"w_out$", ("ffn", "p_embed")),
    (r"moe_w_(in|gate)$", ("experts", "p_embed", "expert_ffn")),
    (r"moe_w_out$", ("experts", "expert_ffn", "p_embed")),
    (r"router$", ("p_embed", None)),
    (r"feat_proj$", ("p_embed", "p_embed")),
)

# parameter-only logical axes
_PARAM_AXES = {
    "p_embed": lambda: "embed_fsdp" if _CTX.fsdp else None,
    "kv_heads_p": lambda: "kv_heads",
}


def _resolve_param_axes(axes: Sequence[Optional[str]]) -> Tuple[Optional[str], ...]:
    out = []
    for a in axes:
        if a in _PARAM_AXES:
            out.append(_PARAM_AXES[a]())
        else:
            out.append(a)
    return tuple(out)


def param_logical_axes(path: str, ndim: int) -> Tuple[Optional[str], ...]:
    """Logical axes for a parameter/state leaf given its '/'-joined path and
    rank. Optimizer-state suffixes map onto the base parameter's axes:
    Adafactor row stats (/vr) drop the last axis, column stats (/vc) drop the
    second-to-last; int8 moments (/q) inherit, their scales (/s) replicate.
    """
    stat = None
    for suffix in ("/vr", "/vc", "/q", "/s"):
        if path.endswith(suffix):
            stat = suffix[1:]
            path = path[: -len(suffix)]
            break
    if stat == "s":
        return (None,) * ndim

    def base_axes(nd):
        for pat, axes in _PARAM_RULES:
            if re.search(pat, path):
                axes = _resolve_param_axes(axes)
                if nd == len(axes):
                    return axes
                if nd > len(axes):
                    return (None,) * (nd - len(axes)) + axes
                return axes[-nd:] if nd > 0 else ()
        return (None,) * nd

    if stat == "vr":
        return base_axes(ndim + 1)[:-1]
    if stat == "vc":
        ax = base_axes(ndim + 1)
        return ax[:-2] + ax[-1:]
    return base_axes(ndim)


def param_pspec(path: str, ndim: int, mesh: Optional[Mesh] = None) -> P:
    return logical_to_pspec(param_logical_axes(path, ndim), mesh)


def tree_paths(tree) -> Dict[str, object]:
    """Flatten a pytree into {'/'.join(path): leaf}."""
    flat = {}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        parts = []
        for k in kp:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            else:
                parts.append(str(k))
        flat["/".join(parts)] = leaf
    return flat


def params_shardings(params, mesh: Optional[Mesh] = None):
    """NamedSharding pytree for a parameter/state pytree (path-name rules,
    divisibility-fitted). Works for params, optimizer states, caches."""
    mesh = mesh or _CTX.mesh
    assert mesh is not None, "params_shardings needs a mesh"

    def one(kp, leaf):
        parts = []
        for k in kp:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
        path = "/".join(parts)
        spec = param_pspec(path, leaf.ndim, mesh)
        return NamedSharding(mesh, fit_spec(spec, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(one, params)


def constrain_params(params):
    """Apply parameter sharding constraints inside jit (by path rules)."""
    mesh = _CTX.mesh
    if mesh is None:
        return params

    def one(kp, leaf):
        parts = [str(k.key) for k in kp if hasattr(k, "key")]
        spec = fit_spec(param_pspec("/".join(parts), leaf.ndim, mesh),
                        leaf.shape, mesh)
        return jax.lax.with_sharding_constraint(leaf, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map_with_path(one, params)
