"""GPipe-style pipeline parallelism over the ``pod`` axis.

For multi-pod meshes the pod axis can act as pure extra data parallelism
(default) or as pipeline stages (MeshConfig.pod_role="pipeline"): the layer
stack is split into S contiguous stages, microbatches stream through with
``collective_permute`` hops between stage owners, and the bubble fraction is
(S-1)/(M+S-1) for M microbatches.

Implementation: shard_map over the pod axis; each pod holds its stage's
parameters (leading stage axis sharded over pod); a lax.fori over
M + S - 1 ticks runs the classic schedule; activations hop via ppermute.
Compute/communication overlap: the ppermute of tick t runs concurrently
with the next tick's stage compute (double-buffered carry).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as sh
from repro.distributed import compat


def pipeline_apply(stage_fn: Callable, stage_params, x_microbatches,
                   axis: str = "pod"):
    """Run microbatches through pipeline stages owned by pod ranks.

    stage_fn(params_slice, x) -> y       (one stage's computation)
    stage_params: pytree with leading axis [S, ...] sharded over ``axis``.
    x_microbatches: [M, mb, ...] (replicated over ``axis``).
    Returns [M, mb, ...] outputs of the final stage.
    """
    mesh = sh.active_mesh()
    assert mesh is not None
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    s = sizes[axis]
    m = x_microbatches.shape[0]

    def shard_fn(params, xs):
        params = jax.tree.map(lambda a: a[0], params)   # my stage's slice
        rank = jax.lax.axis_index(axis)
        n_ticks = m + s - 1
        buf = jnp.zeros_like(xs[0])
        buf = compat.pvary(buf, (axis,))
        outs = compat.pvary(jnp.zeros_like(xs), (axis,))

        def tick(t, carry):
            buf, outs = carry
            # stage 0 injects microbatch t (when in range)
            mb_idx = jnp.clip(t, 0, m - 1)
            inject = jnp.where(rank == 0,
                               jnp.where(t < m, 1.0, 0.0), 0.0)
            x_in = jnp.where(inject > 0, xs[mb_idx], buf)
            y = stage_fn(params, x_in)
            # last stage records output of microbatch t - (s-1)
            out_idx = jnp.clip(t - (s - 1), 0, m - 1)
            record = (rank == s - 1) & (t >= s - 1)
            outs = jax.lax.cond(
                record,
                lambda o: o.at[out_idx].set(y),
                lambda o: o, outs)
            # hop activations forward one stage
            buf = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % s) for i in range(s)])
            return buf, outs

        _, outs = jax.lax.fori_loop(0, n_ticks, tick, (buf, outs))
        # replicate results from the last stage to all pods
        outs = jax.lax.psum(
            jnp.where(rank == s - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    other = tuple(a for a in mesh.axis_names if a != axis)
    pspec = P(axis)
    xspec = P()
    return compat.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: pspec, stage_params), xspec),
        out_specs=xspec, check_vma=True,
    )(stage_params, x_microbatches)
