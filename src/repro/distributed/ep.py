"""Expert parallelism via shard_map: the production MoE path (§Perf).

Observation: in our TP layout the token activations are REPLICATED across
the ``model`` axis (they are sharded over data/pod only). So expert
parallelism needs **no all_to_all at all**: every model-rank already holds
every token; it computes only the experts it owns (capacity-gathered
locally), emits a partial combine, and ONE psum([T_loc, d]) per layer merges
expert contributions. Communication per MoE layer drops from
"all-gather the expert weights" (5.8 GB/layer for grok-1 serving under
naive pjit — measured in the §Perf diagnosis) to a ~14 MB activation psum.

Expert-to-rank mapping handles both regimes:
  * E %  M == 0: rank r owns experts [r*E_loc, (r+1)*E_loc)
  * M %  E == 0: experts are SPLIT along d_ff: rank r owns the
    (r % split)-th f-slice of expert r // split (SwiGLU is elementwise in
    f, so slicing f across ranks is exact; the psum sums the slices).

Differentiable (shard_map + psum), so the same path serves EP training.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as sh
from repro.distributed import compat
from repro.models.layers import act_fn


def ep_available(cfg) -> bool:
    mesh = sh.active_mesh()
    if mesh is None or cfg.moe is None:
        return False
    ax = sh._CTX.rules.get("experts")
    if isinstance(ax, (tuple, list)):
        ax = ax[0] if ax else None
    if ax is None or ax not in mesh.axis_names:
        return False
    m = dict(zip(mesh.axis_names, mesh.devices.shape))[ax]
    e = cfg.moe.num_experts
    if m <= 1:
        return False
    return e % m == 0 or (m % e == 0 and cfg.d_ff % (m // e) == 0)


def moe_apply_ep(p, x, cfg):
    """x: [B,T,d] -> [B,T,d]; requires ep_available(cfg)."""
    mesh = sh.active_mesh()
    ax = sh._CTX.rules.get("experts")
    ax = ax[0] if isinstance(ax, (tuple, list)) else ax
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    m = sizes[ax]
    e, d, f = cfg.moe.num_experts, cfg.d_model, cfg.d_ff
    k = cfg.moe.top_k
    b, t, _ = x.shape
    x2 = x.reshape(b * t, d)

    # ---- routing (replicated weights; token-sharded activations) ----
    logits = jnp.einsum("td,de->te", x2.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    gates, idx = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), k)
    gates = (gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
             ).astype(x.dtype)

    # ---- expert weight relayout to a leading rank axis of size M ----
    gated = cfg.mlp_gated
    if e % m == 0:
        e_loc, split, f_loc = e // m, 1, f
        w_in = p["moe_w_in"].reshape(m, e_loc, d, f)
        w_gate = p["moe_w_gate"].reshape(m, e_loc, d, f) if gated else None
        w_out = p["moe_w_out"].reshape(m, e_loc, f, d)
    else:
        split = m // e
        e_loc, f_loc = 1, f // split
        w_in = p["moe_w_in"].reshape(e, d, split, f_loc).transpose(
            0, 2, 1, 3).reshape(m, 1, d, f_loc)
        w_gate = (p["moe_w_gate"].reshape(e, d, split, f_loc).transpose(
            0, 2, 1, 3).reshape(m, 1, d, f_loc) if gated else None)
        w_out = p["moe_w_out"].reshape(e, split, f_loc, d).reshape(
            m, 1, f_loc, d)

    batch_axes = []
    prod = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names and (b * t) % (prod * sizes[a]) == 0:
            batch_axes.append(a)
            prod *= sizes[a]
    bspec = tuple(batch_axes) if len(batch_axes) > 1 else (
        batch_axes[0] if batch_axes else None)
    t_loc = (b * t) // prod
    cap = max(8, int(t_loc * k / e * cfg.moe.capacity_factor) + 1)

    wspecs = P(ax)
    cast = x.dtype

    def shard_fn(x_loc, gates_loc, idx_loc, wi, wg, wo):
        rank = jax.lax.axis_index(ax)
        wi = wi[0]                       # shard_map keeps rank dim as size 1
        wo = wo[0]
        wg = wg[0] if gated else None
        y = jnp.zeros_like(x_loc)
        y = compat.pvary(y, (ax,))
        flat_idx = idx_loc.reshape(-1)                       # [T_loc*k]
        flat_gate = gates_loc.reshape(-1)
        src = jnp.repeat(jnp.arange(t_loc), k)
        for j in range(e_loc):
            e_mine = (rank * e_loc + j) if split == 1 else rank // split
            sel = flat_idx == e_mine                         # [T_loc*k]
            pos = jnp.cumsum(sel.astype(jnp.int32)) - 1
            ok = sel & (pos < cap)
            wpos = jnp.where(ok, pos, cap)
            xin0 = compat.pvary(jnp.zeros((cap + 1, d), cast), (ax,))
            xin = xin0.at[wpos].add(
                jnp.where(ok[:, None], x_loc[src], 0))[:cap]
            h = xin @ wi[j].astype(cast)
            if gated:
                h = act_fn(cfg.mlp_act)(xin @ wg[j].astype(cast)) * h
            else:
                h = act_fn(cfg.mlp_act)(h)
            xout = h @ wo[j].astype(cast)                    # [cap, d]
            picked = jnp.where(ok[:, None],
                               xout[jnp.clip(wpos, 0, cap - 1)], 0)
            y = y.at[src].add(picked * flat_gate[:, None])
        return jax.lax.psum(y, ax)

    y2 = compat.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(bspec), P(bspec), P(bspec), wspecs, wspecs
                  if gated else P(), wspecs),
        out_specs=P(bspec),
        check_vma=True,
    )(x2, gates, idx,
      jax.lax.with_sharding_constraint(
          w_in, jax.sharding.NamedSharding(mesh, P(ax))),
      (jax.lax.with_sharding_constraint(
          w_gate, jax.sharding.NamedSharding(mesh, P(ax)))
       if gated else jnp.zeros((), x.dtype)),
      jax.lax.with_sharding_constraint(
          w_out, jax.sharding.NamedSharding(mesh, P(ax))))
    return y2.reshape(b, t, d)
