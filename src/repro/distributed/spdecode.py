"""KV-sequence-parallel decode attention (flash-decoding across chips).

At decode time the KV cache dominates memory and bandwidth; GQA archs with
2-8 KV heads cannot fill a 16-way tensor axis, so we shard the cache along
the SEQUENCE axis of the ``model`` mesh axis instead. Each shard computes
flash partials (acc, m, l) over its cache slice; partials merge across the
axis with a log-sum-exp psum (tiny: O(q_tokens * head_dim) per chip vs the
KV bytes that stay put). The in-flight tree/block KV is replicated, its
contribution computed identically on every shard and merged locally.

This is the TPU analogue of the paper's cascade attention phase-1/phase-2
split (shared long prefix once + small tree-local part), extended across
chips — see DESIGN §3.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.attention import (NEG_INF, attend, attend_chunked,
                                    merge_attn_stats, softcap)
from repro.distributed import sharding as sh
from repro.distributed import compat


def kv_seq_axis() -> Optional[str]:
    """The mesh axis the KV cache sequence dim is sharded over, if any."""
    mesh = sh.active_mesh()
    if mesh is None:
        return None
    ax = sh._CTX.rules.get("kv_seq")
    if isinstance(ax, (tuple, list)):
        ax = ax[0] if ax else None
    if ax is None or ax not in mesh.axis_names:
        return None
    return ax


def kv_seq_shards() -> int:
    """Size of the kv_seq mesh axis (1 without a mesh / unsharded)."""
    mesh = sh.active_mesh()
    axis = kv_seq_axis()
    if mesh is None or axis is None:
        return 1
    return dict(zip(mesh.axis_names, mesh.devices.shape))[axis]


# Trace-time log of per-shard LSE-merge collective payload bytes: one entry
# per ``sharded_paged_cache_attend`` call traced (shape-only, so it is
# stable across executions of the same trace). The serving engine snapshots
# this around its first cycle dispatch to attribute decode-collective bytes
# per cycle; see ``ServingEngine.dispatch_cycle``.
PAYLOAD_TRACE: list = []


def sharded_cache_attend(q, cache_k, cache_v, blk_k, blk_v, *, cache_len,
                         q_abs, window, attn_softcap, blk_mask, rolling,
                         kv_chunk: int = 1024, merge_dtype=jnp.bfloat16):
    """Single-softmax attention over [sharded cache ++ replicated block].

    q: [B,Tq,Hq,Dh] (replicated over model axis)
    cache_k/v: [B,S,Hkv,Dh] logically; S sharded over the kv_seq axis
    blk_k/v: [B,Tblk,Hkv,Dh] replicated; blk_mask [B,Tq,Tblk] or [Tq,Tblk]
    cache_len: [B] valid cache length; q_abs: [B,Tq] absolute positions.

    merge_dtype: dtype of the cross-chip LSE-merge payload. bf16 halves the
    dominant decode collective (partials psum) at bf16-model accuracy
    (§Perf iteration 2); pass float32 for exact merging.
    """
    mesh = sh.active_mesh()
    axis = kv_seq_axis()
    assert mesh is not None and axis is not None
    b, tq, hq, dh = q.shape
    hkv = cache_k.shape[2]
    if blk_mask is not None and blk_mask.ndim == 2:
        blk_mask = jnp.broadcast_to(blk_mask[None], (b, tq, blk_mask.shape[-1]))
    clen = jnp.asarray(cache_len)
    if clen.ndim == 0:
        clen = jnp.full((b,), clen)
    qa = jnp.asarray(q_abs)
    if qa.ndim == 1:
        qa = jnp.broadcast_to(qa[None], (b, tq))
    cap = cache_k.shape[1]

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    batch_axes = []
    prod = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names and b % (prod * sizes[a]) == 0:
            batch_axes.append(a)
            prod *= sizes[a]
    bspec = tuple(batch_axes) if len(batch_axes) > 1 else (
        batch_axes[0] if batch_axes else None)

    vary_cache = tuple(batch_axes) + (axis,)
    vary_blk = tuple(batch_axes)

    def shard_fn(qs, ck, cv, bk, bv, cl, qab, bm):
        ax_idx = jax.lax.axis_index(axis)
        s_loc = ck.shape[1]
        offset = ax_idx * s_loc
        # ---- cache slice partials ----
        # mask by absolute key position (rolling caches store position
        # p at slot p % cap, recovered against the local slot offset)
        acc, m, l = _cache_stats(compat.pvary(qs, (axis,)), ck, cv,
                                 offset=offset, cap=cap,
                                 clen=cl, qab=qab, window=window,
                                 attn_softcap=attn_softcap, rolling=rolling,
                                 kv_chunk=kv_chunk, vary_axes=vary_cache)
        acc_g, m_g, l_g = _axis_lse_merge(acc, m, l, axis, merge_dtype)
        # ---- replicated block part (computed identically per shard) ----
        acc_b, m_b, l_b = attend_chunked(
            qs, bk, bv, causal=False, q_offset=0, extra_mask=bm,
            attn_softcap=attn_softcap, kv_chunk=max(bk.shape[1], 8),
            return_stats=True, vary_axes=vary_blk)
        out = merge_attn_stats([(acc_g, m_g, l_g), (acc_b, m_b, l_b)],
                               qs.shape, qs.dtype)
        return out

    # check_vma=True: psum/pmax establish replication over the kv_seq axis,
    # so shard_map emits NO output all-gather (the check_vma=False baseline
    # re-gathered the merged output redundantly — §Perf iteration 1).
    return compat.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(bspec), P(bspec, axis), P(bspec, axis), P(bspec),
                  P(bspec), P(bspec), P(bspec), P(bspec)),
        out_specs=P(bspec),
        check_vma=True,
    )(q, cache_k, cache_v, blk_k, blk_v, clen, qa, blk_mask)


def _axis_lse_merge(acc, m, l, axis, merge_dtype):
    """LSE-merge flash partials across a mesh axis (pmax + 2 psums).

    Partials are normalized by the global max first so the psum payload can
    travel in bf16 without range loss (values in [0, l_local]); pass
    ``merge_dtype=float32`` for exact merging (the serving engine's
    default — token identity with the single-device engine requires argmax
    stability, not just rtol-closeness).
    """
    m_g = jax.lax.pmax(m, axis)
    corr = jnp.exp(m - m_g)
    l_g = jax.lax.psum((l * corr).astype(merge_dtype),
                       axis).astype(jnp.float32)
    acc_g = jax.lax.psum((acc * corr[..., None]).astype(merge_dtype),
                         axis).astype(jnp.float32)
    return acc_g, m_g, l_g


def sharded_paged_cache_attend(q, pool_k, pool_v, table, blk_k, blk_v, *,
                               cache_len, q_abs, attn_softcap, blk_mask,
                               page_size: int, kv_chunk: int = 1024,
                               merge_dtype=jnp.float32,
                               read_impl: str = "gather",
                               interpret=None):
    """Paged cascade-verify attention under shard_map: single-softmax over
    [paged cache ++ replicated block] with the pool's page *payloads*
    sharded along the kv_seq axis.

    Layout ("page identity is global, page bytes are per-shard"): the pool
    is sharded on its within-page position axis — shard i of P holds slots
    ``[i*page_loc, (i+1)*page_loc)`` of EVERY page, ``page_loc =
    page_size // P``. Page tables stay host-global integer ids, so one
    replicated gather per shard resolves its local view and no cross-shard
    page traffic exists; the absolute position of local flat slot t is
    ``(t // page_loc)*page_size + i*page_loc + (t % page_loc)``. Shards'
    flash partials merge with the same LSE psum as the dense path; the
    in-flight tree/block KV is replicated and merged locally.

    q: [B,Tq,Hq,Dh] replicated; pool_k/v: [P_pages, page, Hkv, Dh]
    logically (within-page axis sharded over kv_seq); table: [B, MP] int32
    page ids (PAGE_SENTINEL rows masked out by ``cache_len``);
    blk_k/v: [B,Tblk,Hkv,Dh]; cache_len [B]; q_abs [B,Tq] or [Tq].

    Non-rolling global-attention reads only (the prefix cache's gating) —
    serves both the verifier's paged KV layers and the drafter's paged
    feature caches (``core.drafter.drafter_forward``, which are always
    non-rolling and windowless); ``merge_dtype`` defaults to float32 —
    see :func:`_axis_lse_merge`.

    ``read_impl`` selects how each shard reads its local pool slice:
    "gather" (default) materializes the local logical view via
    ``pool_view``; "pallas" runs the paged cascade phase-1 kernel directly
    on the local pool + global page table, placing logical page ``i`` at
    absolute positions ``i*page_size + ax_idx*page_loc + [0, page_loc)``
    via the kernel's pos_stride/pos_offset parameters. Both feed the SAME
    fp32 LSE psum merge, so per-request tokens are identical. The pallas
    branch runs the shard_map with ``check_vma=False`` (jax has no
    replication rule for pallas_call); outputs are psum-merged, hence
    replicated, either way.
    """
    from repro.models import kvcache as kvc
    if read_impl == "pallas":
        from repro.kernels import cascade_attention as casc
        from repro.kernels import ops as kops
        interpret = (kops._default_interpret() if interpret is None
                     else interpret)

    mesh = sh.active_mesh()
    axis = kv_seq_axis()
    assert mesh is not None and axis is not None
    nsh = kv_seq_shards()
    assert page_size % nsh == 0, (page_size, nsh)
    page_loc = page_size // nsh
    b, tq, hq, dh = q.shape
    hkv = pool_k.shape[-2]
    mp = table.shape[1]
    if blk_mask is not None and blk_mask.ndim == 2:
        blk_mask = jnp.broadcast_to(blk_mask[None],
                                    (b, tq, blk_mask.shape[-1]))
    clen = jnp.asarray(cache_len)
    if clen.ndim == 0:
        clen = jnp.full((b,), clen)
    qa = jnp.asarray(q_abs)
    if qa.ndim == 1:
        qa = jnp.broadcast_to(qa[None], (b, tq))

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    batch_axes = []
    prod = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names and b % (prod * sizes[a]) == 0:
            batch_axes.append(a)
            prod *= sizes[a]
    bspec = tuple(batch_axes) if len(batch_axes) > 1 else (
        batch_axes[0] if batch_axes else None)
    vary_cache = tuple(batch_axes) + (axis,)
    vary_blk = tuple(batch_axes)

    # per-shard collective payload: acc+l in merge_dtype, m via fp32 pmax
    md = jnp.dtype(merge_dtype).itemsize
    PAYLOAD_TRACE.append(int(b * hkv * (hq // hkv) * tq * ((dh + 1) * md + 4)))

    def shard_fn(qs, pk, pv, tbl, bk, bv, cl, qab, bm):
        ax_idx = jax.lax.axis_index(axis)
        if read_impl == "pallas":
            # kernel on the local pool slice: one grid step per local page
            # run; stride/offset place the run at its absolute positions
            acc, m, l = casc.cascade_phase1_paged(
                jnp.swapaxes(compat.pvary(qs, (axis,)), 1, 2),
                jnp.swapaxes(pk, 1, 2), jnp.swapaxes(pv, 1, 2),
                compat.pvary(tbl, (axis,)),
                cache_len=compat.pvary(cl, (axis,)),
                q_abs=compat.pvary(qab, (axis,)),
                window=None, attn_softcap=attn_softcap,
                pos_stride=page_size, pos_offset=ax_idx * page_loc,
                interpret=interpret)
            # local split merge, then reshape [B,Hq,...] -> the
            # attend_chunked stats layout [B,Hkv,G,...] (head h = (h//g, h%g))
            m_l = m.max(axis=2)
            cr = jnp.exp(m - m_l[:, :, None])
            l_l = (l * cr).sum(axis=2)
            acc_l = (acc * cr[..., None]).sum(axis=2)
            bl, g = qs.shape[0], hq // hkv
            acc = acc_l.reshape(bl, hkv, g, tq, dh)
            m = m_l.reshape(bl, hkv, g, tq)
            l = l_l.reshape(bl, hkv, g, tq)
        else:
            # local logical view: [B, MP*page_loc, Hkv, Dh] — every page's
            # local slot run, in page-table order
            vk = kvc.pool_view(pk, tbl)
            vv = kvc.pool_view(pv, tbl)
            t = jnp.arange(mp * page_loc)
            pos = ((t // page_loc) * page_size + ax_idx * page_loc
                   + (t % page_loc))[None, None, :]
            acc, m, l = _cache_stats(
                compat.pvary(qs, (axis,)), vk, vv, offset=0,
                cap=mp * page_size, clen=cl, qab=qab, window=None,
                attn_softcap=attn_softcap, rolling=False, kv_chunk=kv_chunk,
                vary_axes=vary_cache, pos=pos)
        acc_g, m_g, l_g = _axis_lse_merge(acc, m, l, axis, merge_dtype)
        acc_b, m_b, l_b = attend_chunked(
            qs, bk, bv, causal=False, q_offset=0, extra_mask=bm,
            attn_softcap=attn_softcap, kv_chunk=max(bk.shape[1], 8),
            return_stats=True, vary_axes=vary_blk)
        return merge_attn_stats([(acc_g, m_g, l_g), (acc_b, m_b, l_b)],
                                qs.shape, qs.dtype)

    return compat.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(bspec), P(None, axis), P(None, axis), P(bspec),
                  P(bspec), P(bspec), P(bspec), P(bspec), P(bspec)),
        out_specs=P(bspec),
        check_vma=(read_impl != "pallas"),
    )(q, pool_k, pool_v, table, blk_k, blk_v, clen, qa, blk_mask)


def _cache_stats(q, k, v, *, offset, cap, clen, qab, window, attn_softcap,
                 rolling, kv_chunk, vary_axes=(), pos=None):
    """Flash partials over a local cache slice with absolute-position masks.

    ``pos``: optional precomputed absolute key positions [1,1,S_loc] (the
    paged layout's positions are non-contiguous per shard); defaults to the
    contiguous ``offset + arange`` of a sequence-sliced dense cache.
    """
    b, tq = q.shape[:2]
    s_loc = k.shape[1]
    if pos is None:
        jc = offset + jnp.arange(s_loc)[None, None, :]      # global slot ids
    else:
        jc = pos
    qpos = qab[:, :, None]
    cl = clen[:, None, None]
    if rolling:
        last = cl - 1
        abs_kpos = last - jnp.mod(last - jc, cap)
        ok = (abs_kpos >= 0) & (abs_kpos < cl) & (abs_kpos <= qpos)
        if window is not None:
            ok &= abs_kpos > (qpos - window)
    else:
        ok = (jc < cl) & (jc <= qpos)
        if window is not None:
            ok &= jc > (qpos - window)
    return attend_chunked(q, k, v, causal=False, q_offset=0, extra_mask=ok,
                          attn_softcap=attn_softcap,
                          kv_chunk=min(kv_chunk, s_loc), return_stats=True,
                          vary_axes=vary_axes)
