"""Compressed gradient all-reduce with error feedback (distributed-opt).

int8-quantized gradient exchange over the data axis: each shard quantizes
its local gradient block-wise to int8 (+fp32 scales), psums the int8 payload
widened to int32 (lossless accumulation), and dequantizes. Residual
quantization error is carried in an error-feedback buffer and re-added next
step (Karimireddy et al., "Error Feedback Fixes SignSGD", arXiv:1901.09847) —
keeping convergence unbiased while cutting gradient traffic ~4x vs fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as sh

_BLOCK = 256


def compressed_grad_allreduce(grads, err_state, axis: str = "data"):
    """grads/err_state: matching pytrees of LOCAL (unreduced) fp32 grads.

    Two-phase shared-scale scheme: (1) pmax the per-block amax -> shared
    scale s* (tiny fp32 traffic); (2) psum the int8 payload widened to int32
    (lossless accumulation; the wire carries 1 byte/elem + log-width);
    dequant acc * s* / n. Each shard's own quantization residual goes into
    its error-feedback buffer and is re-added next step, so the compressor
    is unbiased in the EF sense. Returns (mean_grads, new_err_state).
    Must run inside shard_map over ``axis``.
    """
    n = jax.lax.psum(1, axis)

    def one(g, e):
        g = g.astype(jnp.float32) + e
        flat = g.reshape(-1)
        pad = (-flat.size) % _BLOCK
        fp = jnp.pad(flat, (0, pad)).reshape(-1, _BLOCK)
        amax = jnp.max(jnp.abs(fp), axis=1, keepdims=True) + 1e-12
        amax = jax.lax.pmax(amax, axis)                 # shared block scale
        scale = amax / 127.0
        q = jnp.clip(jnp.round(fp / scale), -127, 127).astype(jnp.int8)
        deq_local = (q.astype(jnp.float32) * scale).reshape(-1)[: flat.size]
        acc = jax.lax.psum(q.astype(jnp.int32), axis)   # lossless in int32
        mean = (acc.astype(jnp.float32) * scale / n).reshape(-1)[: flat.size]
        new_e = g - deq_local.reshape(g.shape)          # local EF residual
        return mean.reshape(g.shape), new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tdef, [o[0] for o in out]),
            jax.tree.unflatten(tdef, [o[1] for o in out]))


def init_error_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
