"""The paper's own setting, reduced for CPU-scale empirical validation.

Qwen3-8B-analogue target (small) + DFlash-style drafter configs used by the
training / benchmark drivers. Full-scale Qwen3-8B-like config included for
the dry-run path as 'paper-target'.
"""
from repro.config.base import Family, ModelConfig
from repro.config.registry import register
from repro.core.drafter import DrafterConfig


def full() -> ModelConfig:
    # Qwen3-8B-shaped: 36L, d=4096, 32H/8KV, ff 12288, vocab 151936
    return ModelConfig(
        name="paper-target", family=Family.DENSE,
        num_layers=36, d_model=4096, num_heads=32, num_kv_heads=8,
        d_ff=12288, vocab_size=151936, qk_norm=True, rope_theta=1e6,
        max_seq_len=32768,
    )


def smoke() -> ModelConfig:
    """The small target actually trained in the empirical study."""
    return ModelConfig(
        name="paper-target-small", family=Family.DENSE,
        num_layers=4, d_model=256, num_heads=4, num_kv_heads=2,
        d_ff=768, vocab_size=512, qk_norm=True, remat=False,
        max_seq_len=2048, dtype="float32",
    )


def drafter_small(gamma: int = 16, causal: bool = False) -> DrafterConfig:
    t = smoke()
    return DrafterConfig(
        d_model=192, num_layers=2, num_heads=4, num_kv_heads=2,
        d_ff=512, vocab_size=t.vocab_size,
        target_feature_dim=3 * t.d_model, gamma=gamma, causal=causal,
        dtype="float32",
    )


register("paper-target", full, smoke)
