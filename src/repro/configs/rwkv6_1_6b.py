"""rwkv6-1.6b "Finch" [ssm] — attention-free, data-dependent decay.
[arXiv:2404.05892; unverified]"""
from repro.config.base import Family, ModelConfig
from repro.config.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b", family=Family.SSM,
        num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32,
        head_dim=64, d_ff=7168, vocab_size=65536,
        layer_pattern=("rwkv",), rwkv_head_dim=64, max_seq_len=1048576,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-smoke", family=Family.SSM,
        num_layers=3, d_model=128, num_heads=8, num_kv_heads=8, head_dim=16,
        d_ff=256, vocab_size=512, layer_pattern=("rwkv",), rwkv_head_dim=16,
        remat=False, max_seq_len=128,
    )


register("rwkv6-1.6b", full, smoke)
