"""gemma2-2b [dense] — local+global alternating, logit softcaps, post-norms.
[arXiv:2408.00118; hf]"""
from repro.config.base import Family, ModelConfig
from repro.config.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b", family=Family.DENSE,
        num_layers=26, d_model=2304, num_heads=8, num_kv_heads=4,
        head_dim=256, d_ff=9216, vocab_size=256000,
        layer_pattern=("local", "global"), sliding_window=4096,
        logit_softcap=30.0, attn_softcap=50.0, use_post_norm=True,
        mlp_act="gelu", tie_embeddings=True, max_seq_len=8192,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b-smoke", family=Family.DENSE,
        num_layers=4, d_model=128, num_heads=4, num_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512, layer_pattern=("local", "global"),
        sliding_window=16, logit_softcap=30.0, attn_softcap=50.0,
        use_post_norm=True, mlp_act="gelu", tie_embeddings=True,
        remat=False, max_seq_len=128,
    )


register("gemma2-2b", full, smoke)
