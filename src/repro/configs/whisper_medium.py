"""whisper-medium [audio] — enc-dec; conv frontend is a STUB (``input_specs``
provides precomputed frame embeddings). [arXiv:2212.04356; unverified]"""
from repro.config.base import Family, ModelConfig
from repro.config.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium", family=Family.AUDIO,
        num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
        d_ff=4096, vocab_size=51865,
        is_encoder_decoder=True, enc_num_layers=24, enc_max_len=1500,
        cross_attn_every=1, mlp_gated=False, mlp_act="gelu",
        max_seq_len=32768,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium-smoke", family=Family.AUDIO,
        num_layers=3, d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=256, vocab_size=512,
        is_encoder_decoder=True, enc_num_layers=2, enc_max_len=32,
        cross_attn_every=1, mlp_gated=False, mlp_act="gelu",
        remat=False, max_seq_len=128,
    )


register("whisper-medium", full, smoke)
