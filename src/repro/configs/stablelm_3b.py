"""stablelm-3b [dense] — MHA (kv=heads). [hf:stabilityai/stablelm-3b;
unverified]"""
from repro.config.base import Family, ModelConfig
from repro.config.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="stablelm-3b", family=Family.DENSE,
        num_layers=32, d_model=2560, num_heads=32, num_kv_heads=32,
        d_ff=6912, vocab_size=50304, max_seq_len=4096,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="stablelm-3b-smoke", family=Family.DENSE,
        num_layers=4, d_model=128, num_heads=8, num_kv_heads=8,
        d_ff=256, vocab_size=512, remat=False, max_seq_len=128,
    )


register("stablelm-3b", full, smoke)
