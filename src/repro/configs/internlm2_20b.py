"""internlm2-20b [dense] — GQA kv=8. [arXiv:2403.17297; hf]"""
from repro.config.base import Family, ModelConfig
from repro.config.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="internlm2-20b", family=Family.DENSE,
        num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8,
        d_ff=16384, vocab_size=92544, rope_theta=1e6, max_seq_len=32768,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internlm2-20b-smoke", family=Family.DENSE,
        num_layers=4, d_model=128, num_heads=8, num_kv_heads=2,
        d_ff=256, vocab_size=512, remat=False, max_seq_len=128,
    )


register("internlm2-20b", full, smoke)
