"""grok-1-314b [moe] — 8 experts top-2. [hf:xai-org/grok-1; unverified]"""
from repro.config.base import Family, ModelConfig, MoEConfig
from repro.config.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b", family=Family.MOE,
        num_layers=64, d_model=6144, num_heads=48, num_kv_heads=8,
        head_dim=128, d_ff=32768, vocab_size=131072,
        moe=MoEConfig(num_experts=8, top_k=2, capacity_factor=1.25,
                      dispatch="scatter"),
        max_seq_len=8192,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="grok-1-smoke", family=Family.MOE,
        num_layers=3, d_model=128, num_heads=8, num_kv_heads=2,
        d_ff=256, vocab_size=512,
        moe=MoEConfig(num_experts=4, top_k=2, capacity_factor=2.0,
                      dispatch="scatter"),
        remat=False, max_seq_len=128,
    )


register("grok-1-314b", full, smoke)
