"""recurrentgemma-2b [hybrid] — RG-LRU + local attn, 1 attn : 2 recurrent.
MQA kv=1. [arXiv:2402.19427; hf]"""
from repro.config.base import Family, ModelConfig
from repro.config.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b", family=Family.HYBRID,
        num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1,
        head_dim=256, d_ff=7680, vocab_size=256000,
        layer_pattern=("recurrent", "recurrent", "local"),
        sliding_window=2048, rglru_width=2560, conv1d_width=4,
        mlp_act="gelu", tie_embeddings=True, max_seq_len=524288,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b-smoke", family=Family.HYBRID,
        num_layers=5, d_model=128, num_heads=4, num_kv_heads=1, head_dim=32,
        d_ff=256, vocab_size=512,
        layer_pattern=("recurrent", "recurrent", "local"),
        sliding_window=16, rglru_width=128, conv1d_width=4,
        mlp_act="gelu", tie_embeddings=True, remat=False, max_seq_len=128,
    )


register("recurrentgemma-2b", full, smoke)
