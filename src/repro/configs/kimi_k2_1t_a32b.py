"""kimi-k2-1t-a32b [moe] — trillion-param MoE, 384 experts top-8 + 1 shared.
[arXiv:2501.kimi2; unverified]"""
from repro.config.base import Family, ModelConfig, MoEConfig
from repro.config.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b", family=Family.MOE,
        num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8,
        head_dim=128, d_ff=2048, vocab_size=163840,
        moe=MoEConfig(num_experts=384, top_k=8, capacity_factor=1.25,
                      dispatch="scatter", num_shared_experts=1),
        max_seq_len=131072,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-smoke", family=Family.MOE,
        num_layers=3, d_model=128, num_heads=8, num_kv_heads=2,
        d_ff=64, vocab_size=512,
        moe=MoEConfig(num_experts=8, top_k=2, capacity_factor=2.0,
                      dispatch="scatter", num_shared_experts=1),
        remat=False, max_seq_len=128,
    )


register("kimi-k2-1t-a32b", full, smoke)
