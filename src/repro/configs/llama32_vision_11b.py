"""llama-3.2-vision-11b [vlm] — cross-attn image layers every 5th block.
Vision frontend is a STUB: ``input_specs`` provides precomputed patch
embeddings (projected to d_model). [hf:meta-llama/Llama-3.2-11B-Vision;
unverified]"""
from repro.config.base import Family, ModelConfig
from repro.config.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b", family=Family.VLM,
        num_layers=40, d_model=4096, num_heads=32, num_kv_heads=8,
        d_ff=14336, vocab_size=128256, cross_attn_every=5,
        num_vision_tokens=1601, rope_theta=5e5, max_seq_len=131072,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-smoke", family=Family.VLM,
        num_layers=5, d_model=128, num_heads=8, num_kv_heads=2,
        d_ff=256, vocab_size=512, cross_attn_every=5,
        num_vision_tokens=16, remat=False, max_seq_len=128,
    )


register("llama-3.2-vision-11b", full, smoke)
