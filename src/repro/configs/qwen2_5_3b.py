"""qwen2.5-3b [dense] — GQA kv=2, QKV bias. [hf:Qwen/Qwen2.5-3B; hf]"""
from repro.config.base import Family, ModelConfig
from repro.config.registry import register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-3b", family=Family.DENSE,
        num_layers=36, d_model=2048, num_heads=16, num_kv_heads=2,
        d_ff=11008, vocab_size=151936, qkv_bias=True,
        rope_theta=1e6, max_seq_len=32768,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-3b-smoke", family=Family.DENSE,
        num_layers=4, d_model=128, num_heads=8, num_kv_heads=2,
        d_ff=256, vocab_size=512, qkv_bias=True, remat=False,
        max_seq_len=128,
    )


register("qwen2.5-3b", full, smoke)
