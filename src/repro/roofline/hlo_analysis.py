"""Loop-aware HLO text analyzer.

XLA's ``compiled.cost_analysis()`` visits while-loop bodies ONCE (verified
empirically in this repo), so a scanned 61-layer transformer reports ~1/61 of
its real FLOPs. This analyzer parses ``compiled.as_text()`` and:

  * counts dot FLOPs per computation (2 * prod(result) * contraction),
  * counts collective bytes per op kind (result bytes, with replica-group
    aware factors: all-reduce 2(n-1)/n, all-gather/reduce-scatter (n-1)/n,
    all-to-all (n-1)/n, collective-permute 1),
  * counts gather / dynamic-slice RESULT bytes (``gather_bytes`` /
    ``dynamic_slice_bytes``) — the HBM attribution for the paged cache
    read path: the "gather" ``attn_impl`` shows capacity-sized pool_view
    gathers every decode cycle, while the Pallas kernel path (interpret
    mode on CPU lowers to a grid loop of page-sized dynamic-slices) only
    ever slices page blocks,
  * multiplies loop bodies by their ``known_trip_count`` (recursively),

yielding per-device totals that are exact for lax.scan-based stacks.
"""
from __future__ import annotations

import json
import re
from typing import Dict, List, Optional, Tuple

_ELT_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes_and_dims(type_str: str) -> Tuple[int, List[List[int]]]:
    """bytes and dims for a (possibly tuple) HLO type string."""
    total = 0
    dims_all = []
    for m in _TYPE_RE.finditer(type_str):
        elt, dims = m.group(1), m.group(2)
        if elt not in _ELT_BYTES:
            continue
        shape = [int(d) for d in dims.split(",") if d] if dims else []
        n = 1
        for d in shape:
            n *= d
        total += n * _ELT_BYTES[elt]
        dims_all.append(shape)
    return total, dims_all


class HloModuleStats:
    def __init__(self, text: str):
        self.computations: Dict[str, List[str]] = {}
        self._parse(text)
        self._cache: Dict[str, Dict[str, float]] = {}
        # (kind, moved_bytes, multiplier, op_name) for attribution
        self.coll_records: List[Tuple[str, float, int, str]] = []

    def _parse(self, text: str):
        cur = None
        for line in text.splitlines():
            if not line.startswith(" ") and "{" in line and "->" in line:
                m = re.match(r"(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->", line)
                if m:
                    cur = m.group(2)
                    self.computations[cur] = []
                    if m.group(1):
                        self.entry = cur
                    continue
            if cur is not None:
                if line.startswith("}"):
                    cur = None
                else:
                    self.computations[cur].append(line.strip())

    # ------------------------------------------------------------------
    def _symbol_shapes(self, lines: List[str]) -> Dict[str, str]:
        syms = {}
        for ln in lines:
            m = re.match(r"(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*((?:\([^)]*\))|"
                         r"(?:[\w\[\]\{\},]+))", ln)
            if m:
                syms[m.group(1)] = m.group(2)
        return syms

    def _analyze_comp(self, name: str, mult: int = 1) -> Dict[str, float]:
        if name in self._cache:
            return self._cache[name]
        out = {"flops": 0.0, "coll_bytes": 0.0, "gather_bytes": 0.0,
               "dynamic_slice_bytes": 0.0}
        for k in _COLLECTIVES:
            out[k] = 0.0
        lines = self.computations.get(name, [])
        syms = self._symbol_shapes(lines)
        for ln in lines:
            # ---- while loops ----
            mw = re.search(r"while\(.*?\),\s*condition=%([\w\.\-]+),\s*"
                           r"body=%([\w\.\-]+)", ln)
            if mw:
                trip = 1
                mt = re.search(r'known_trip_count.*?"n":"(\d+)"', ln)
                if mt:
                    trip = int(mt.group(1))
                body = self._analyze_comp(mw.group(2), mult * trip)
                for k2, v in body.items():
                    out[k2] += trip * v
                continue
            # ---- calls / fusions (recurse; bodies may hold dots) ----
            mc = re.search(r"(?:fusion|call)\(.*?(?:calls|to_apply)="
                           r"%([\w\.\-]+)", ln)
            if mc and mc.group(1) in self.computations:
                sub = self._analyze_comp(mc.group(1), mult)
                for k2, v in sub.items():
                    out[k2] += v
            # ---- dots ----
            md = re.match(r"(?:ROOT\s+)?%[\w\.\-]+\s*=\s*([\w\[\]\{\},]+)"
                          r"\s+dot\(%([\w\.\-]+),\s*%([\w\.\-]+)\),"
                          r".*?lhs_contracting_dims=\{([\d,]*)\}", ln)
            if md:
                res_bytes, res_dims = _shape_bytes_and_dims(md.group(1))
                lhs_type = syms.get(md.group(2), "")
                _, lhs_dims = _shape_bytes_and_dims(lhs_type)
                contr = 1
                if lhs_dims:
                    for d in md.group(4).split(","):
                        if d:
                            contr *= lhs_dims[0][int(d)]
                n_res = 1
                for d in (res_dims[0] if res_dims else []):
                    n_res *= d
                out["flops"] += 2.0 * n_res * contr
                continue
            # ---- gathers / dynamic-slices (cache-read attribution) ----
            ms = re.match(r"(?:ROOT\s+)?%[\w\.\-]+\s*=\s*([\w\[\]\{\},]+)"
                          r"\s+(gather|dynamic-slice)\(", ln)
            if ms:
                nbytes, _ = _shape_bytes_and_dims(ms.group(1))
                out["gather_bytes" if ms.group(2) == "gather"
                    else "dynamic_slice_bytes"] += float(nbytes)
                continue
            # ---- collectives ----
            for kind in _COLLECTIVES:
                if re.search(rf"\s{kind}(-start)?\(", ln):
                    mres = re.match(r"(?:ROOT\s+)?%[\w\.\-]+\s*=\s*"
                                    r"((?:\([^)]*\))|(?:[\w\[\]\{\},]+))", ln)
                    if not mres:
                        break
                    nbytes, _ = _shape_bytes_and_dims(mres.group(1))
                    n = None
                    mg = re.search(r"replica_groups=\[(\d+),(\d+)\]", ln)
                    if mg:
                        n = int(mg.group(2))
                    else:
                        mg2 = re.search(r"replica_groups=\{\{([\d,]+)\}",
                                        ln)
                        if mg2:
                            n = len(mg2.group(1).split(","))
                    n = n or 2
                    if kind == "all-reduce":
                        moved = 2.0 * nbytes * (n - 1) / n
                    elif kind == "collective-permute":
                        moved = float(nbytes)
                    else:
                        moved = float(nbytes) * (n - 1) / n
                    out[kind] += moved
                    out["coll_bytes"] += moved
                    mo = re.search(r'op_name="([^"]*)"', ln)
                    self.coll_records.append(
                        (kind, moved, mult,
                         mo.group(1) if mo else "?"))
                    break
        self._cache[name] = out
        return out

    def totals(self) -> Dict[str, float]:
        entry = getattr(self, "entry", None)
        if entry is None:
            # fallback: largest computation
            entry = max(self.computations, key=lambda c: len(self.computations[c]))
        return self._analyze_comp(entry)


def analyze_hlo_text(text: str) -> Dict[str, float]:
    return HloModuleStats(text).totals()


def top_collectives(text: str, k: int = 15) -> List[Dict]:
    """Largest collective contributors with source attribution — the
    'profile' the perf hillclimb iterates on (no real-TPU trace exists;
    assignment §Pallas-specific hints)."""
    st = HloModuleStats(text)
    st.totals()
    recs = [{"kind": kind, "total_bytes": moved * mult, "trip": mult,
             "op": op[:160]}
            for kind, moved, mult, op in st.coll_records]
    recs.sort(key=lambda r: -r["total_bytes"])
    return recs[:k]
