"""Roofline report generator: reads experiments/dryrun/*.json and renders
the EXPERIMENTS.md tables (§Dry-run + §Roofline)."""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

DRYRUN_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
ARCHS = ("qwen2.5-3b", "internlm2-20b", "gemma2-2b", "stablelm-3b",
         "recurrentgemma-2b", "kimi-k2-1t-a32b", "grok-1-314b",
         "llama-3.2-vision-11b", "whisper-medium", "rwkv6-1.6b")


def load_cells(mesh: str = "single", tag: str = "") -> Dict:
    out = {}
    for arch in ARCHS:
        for shape in SHAPES:
            name = f"{arch}_{shape}_{mesh}" + (f"_{tag}" if tag else "")
            p = DRYRUN_DIR / f"{name}.json"
            if p.exists():
                out[(arch, shape)] = json.loads(p.read_text())
    return out


def _fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def roofline_table(mesh: str = "single", tag: str = "") -> str:
    cells = load_cells(mesh, tag)
    lines = [
        "| arch | shape | compute | memory | collective | dominant | "
        "useful (6ND/HLO) | roofline frac | HBM GB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCHS:
        for shape in SHAPES:
            c = cells.get((arch, shape))
            if c is None:
                lines.append(f"| {arch} | {shape} | (missing) | | | | | | |")
                continue
            if c.get("skipped"):
                lines.append(f"| {arch} | {shape} | skipped "
                             f"(quadratic attn @500k) | | | | | | |")
                continue
            if not c.get("ok"):
                lines.append(f"| {arch} | {shape} | FAILED | | | | | | |")
                continue
            t = c["terms"]
            lines.append(
                f"| {arch} | {shape} | {_fmt_s(t['compute_s'])} | "
                f"{_fmt_s(t['memory_s'])} | {_fmt_s(t['collective_s'])} | "
                f"**{t['dominant']}** | {t['useful_ratio']:.2f} | "
                f"{t['roofline_fraction']:.3f} | "
                f"{c['memory']['peak_estimate_gb']:.1f} |")
    return "\n".join(lines)


def dryrun_table(mesh: str) -> str:
    cells = load_cells(mesh)
    n_ok = sum(1 for c in cells.values() if c.get("ok"))
    n_skip = sum(1 for c in cells.values() if c.get("skipped"))
    lines = [
        f"mesh={mesh}: {n_ok}/{len(cells)} cells ok "
        f"({n_skip} skipped by design)",
        "",
        "| arch | shape | kind | compile_s | args GB/dev | temp GB/dev | "
        "HLO GF/dev | coll MB/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape), c in sorted(cells.items()):
        if c.get("skipped"):
            lines.append(f"| {arch} | {shape} | skip | - | - | - | - | - |")
            continue
        if not c.get("ok"):
            lines.append(f"| {arch} | {shape} | FAIL | - | - | - | - | - |")
            continue
        m = c["memory"]
        lines.append(
            f"| {arch} | {shape} | {c['kind']} | {c.get('compile_s', 0)} | "
            f"{m['argument_bytes_per_dev'] / 2**30:.2f} | "
            f"{m['temp_bytes_per_dev'] / 2**30:.2f} | "
            f"{c['hlo']['flops'] / 1e9:.0f} | "
            f"{c['hlo']['coll_bytes'] / 2**20:.0f} |")
    return "\n".join(lines)


def pick_hillclimb_cells(mesh: str = "single") -> List:
    """worst roofline fraction / most collective-bound / most
    paper-representative (a decode cell of a GQA dense arch)."""
    cells = {k: v for k, v in load_cells(mesh).items()
             if v.get("ok") and not v.get("skipped")}
    worst = min(cells, key=lambda k: cells[k]["terms"]["roofline_fraction"])
    coll = max(cells, key=lambda k: (cells[k]["terms"]["collective_s"]
                                     / max(max(cells[k]["terms"]["compute_s"],
                                               cells[k]["terms"]["memory_s"]),
                                           1e-12)))
    paper = ("qwen2.5-3b", "decode_32k")
    return [worst, coll, paper]


if __name__ == "__main__":
    for mesh in ("single", "multi"):
        print(f"\n===== dryrun {mesh} =====")
        print(dryrun_table(mesh))
    print("\n===== roofline (single pod) =====")
    print(roofline_table("single"))
    print("\nhillclimb picks:", pick_hillclimb_cells())
