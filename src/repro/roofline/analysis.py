"""Roofline terms (assignment §ROOFLINE ANALYSIS).

    compute term    = HLO_FLOPs_per_device / peak_FLOPs_chip
    memory term     = HBM_bytes_per_device / HBM_bw_chip
    collective term = collective_bytes_per_device / link_bw

FLOPs + collective bytes come from the loop-aware HLO analyzer (per-device,
exact for scanned stacks — XLA's cost_analysis undercounts loop bodies and
is recorded as an auxiliary raw value only).

HBM bytes use an analytic traffic model (documented below) because HLO text
can't see inside fusions: weights touched once per step + optimizer traffic +
activation/KV traffic. The model errs on the LOW side for the pure-XLA
reference attention (which spills score tiles); the Pallas kernels remove
that spill on TPU, making the analytic number the deployable one.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.config.base import ModelConfig, ShapeSpec

# TPU v5e per chip
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link


def model_flops(cfg: ModelConfig, tokens: int, train: bool) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE); 2*N*D for inference."""
    n = cfg.active_param_count()
    mult = 6.0 if train else 2.0
    return mult * n * tokens


@dataclasses.dataclass
class Terms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_dev: float
    hbm_bytes_per_dev: float
    coll_bytes_per_dev: float
    model_flops: float
    hlo_flops_global: float
    chips: int

    @property
    def dominant(self) -> str:
        vals = {"compute": self.compute_s, "memory": self.memory_s,
                "collective": self.collective_s}
        return max(vals, key=vals.get)

    @property
    def useful_ratio(self) -> float:
        return (self.model_flops / self.hlo_flops_global
                if self.hlo_flops_global else 0.0)

    @property
    def roofline_fraction(self) -> float:
        """useful-model-FLOP time / roofline-limited step time: how close the
        step is to the compute roofline on its dominant resource."""
        t = max(self.compute_s, self.memory_s, self.collective_s)
        useful = self.model_flops / (PEAK_FLOPS * self.chips)
        return useful / t if t else 0.0

    def as_dict(self):
        d = dataclasses.asdict(self)
        d.update(dominant=self.dominant, useful_ratio=self.useful_ratio,
                 roofline_fraction=self.roofline_fraction)
        return d


def analytic_hbm_bytes(cfg: ModelConfig, shape: ShapeSpec, kind: str,
                       chips: int, param_bytes_per_dev: float,
                       state_bytes_per_dev: float = 0.0,
                       opt_bytes_per_dev: float = 0.0,
                       spec_overhead: float = 1.0) -> float:
    """Per-device HBM traffic model for one step.

    train:   read params (fwd) + read (bwd) + write grads + read+write opt
             + activation traffic (remat: ~2 fwd + 1 bwd passes of layer IO)
    prefill: read params + write KV + activation IO
    decode:  read params + read KV cache (the decisive term) + tree IO
    """
    d = cfg.d_model
    l = cfg.num_layers
    act_bpe = 2.0                                 # bf16 activations
    if kind == "train":
        tokens_dev = shape.global_batch * shape.seq_len / max(chips, 1)
        act_io = 12 * l * tokens_dev * d * act_bpe    # fwd+remat+bwd layer IO
        return (4 * param_bytes_per_dev + 3 * opt_bytes_per_dev + act_io)
    if kind == "prefill":
        tokens_dev = shape.global_batch * shape.seq_len / max(chips, 1)
        kv_write = state_bytes_per_dev
        act_io = 8 * l * tokens_dev * d * act_bpe
        return param_bytes_per_dev + kv_write + act_io
    # decode: one spec-decoding cycle
    return (param_bytes_per_dev * spec_overhead + state_bytes_per_dev)


def derive_terms(cfg: ModelConfig, shape: ShapeSpec, kind: str, chips: int,
                 hlo: Dict[str, float], hbm_bytes_per_dev: float,
                 tokens_for_model_flops: float) -> Terms:
    flops_dev = hlo.get("flops", 0.0)
    coll_dev = hlo.get("coll_bytes", 0.0)
    mf = model_flops(cfg, int(tokens_for_model_flops), kind == "train")
    return Terms(
        compute_s=flops_dev / PEAK_FLOPS,
        memory_s=hbm_bytes_per_dev / HBM_BW,
        collective_s=coll_dev / LINK_BW,
        flops_per_dev=flops_dev,
        hbm_bytes_per_dev=hbm_bytes_per_dev,
        coll_bytes_per_dev=coll_dev,
        model_flops=mf,
        hlo_flops_global=flops_dev * chips,
        chips=chips,
    )
