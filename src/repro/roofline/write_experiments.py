"""Assemble EXPERIMENTS.md from dry-run JSONs + benchmark outputs +
hand-written §Perf narrative.

    PYTHONPATH=src python -m repro.roofline.write_experiments
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.roofline.report import (DRYRUN_DIR, dryrun_table, load_cells,
                                   roofline_table)

ROOT = Path(__file__).resolve().parents[3]


def _opt_cells():
    rows = []
    for f in sorted(DRYRUN_DIR.glob("*_opt*.json")):
        d = json.loads(f.read_text())
        if not d.get("ok"):
            rows.append(f"| {f.stem} | FAILED | | | |")
            continue
        t = d["terms"]
        rows.append(
            f"| {d['arch']} {d['shape']} | {d.get('tag','')} | "
            f"{t['compute_s']:.4f} | {t['collective_s']:.4f} | "
            f"{t['roofline_fraction']:.4f} |")
    return "\n".join(rows)


PERF_NARRATIVE = """\
### Methodology

No real TPU exists in this container, so the "profile" for each iteration is
the **compiled HLO** of the production-mesh dry-run: per-device FLOPs and
collective bytes from the loop-aware analyzer (`repro/roofline/hlo_analysis.py`
— it multiplies while-loop bodies by their `known_trip_count`, which XLA's
`cost_analysis()` does not), plus a per-instruction *top-collectives*
attribution (`--diagnose`) that names the jaxpr source of every collective.
Each iteration states a hypothesis with napkin math, changes one thing,
re-lowers, and records confirmed/refuted.

Three hillclimb cells were selected per the assignment rule:
* **worst roofline fraction**: `grok-1-314b x decode_32k` (0.0022)
* **most collective-bound**: `stablelm-3b x prefill_32k` (coll/compute = 47x)
* **paper-representative**: `qwen2.5-3b x decode_32k` (GQA dense target,
  D2SD serve_step = the paper's core workload)

### Iteration log (hypothesis -> change -> before -> after)

**It-1 — stablelm-3b prefill_32k: KV-cache writes were gather-scatters.**
Diagnosis: 2 x 9.6 GB/layer `all-gather(scatter)` — the KV write used a
general per-example-offset scatter (ragged decode support), which SPMD
cannot partition; it gathered the full K/V and cache. Hypothesis: prefill
always starts at offset 0, so a scalar-offset `dynamic_update_slice` is
partitionable along the kv_seq axis with ZERO communication (napkin: remove
~614 GB/step of gathers, leaving ~2x1.2 GB/layer TP all-reduces =>
collective 15.9 s -> ~2.7 s). Change: `pipeline.prefill` passes scalar
`cache_len=0`. **Measured: collective 15.85 s -> 3.80 s (-76 %), roofline
fraction 0.0073 -> 0.0306 (4.2x). CONFIRMED.**

**It-2 — qwen2.5-3b decode_32k: shard_map hygiene (check_vma) + bf16 merge.**
Diagnosis: per cycle, 2x320 MB TP all-reduces, 323 MB KV-SP LSE-merge psum,
160 MB `shard_map` all-gather. Hypotheses: (a) `check_vma=True` lets
shard_map prove the psum'd output replicated and skip its output gather;
(b) casting merge partials to bf16 halves the psum payload. Napkin: 27 ms ->
~19 ms. Change: spdecode check_vma=True + normalized-bf16 psum payload.
**Measured: 27.3 ms -> 27.3 ms. REFUTED.** Attribution of the new HLO shows
(a) the 160 MB gather is an **input** gather — q is heads-sharded by TP and
must be gathered to enter KV-sequence-parallel attention (minimal, not
removable); (b) the psum still moves 8.97 MB/layer in f32 — XLA reassociates
the convert above the all-reduce. Lesson: VMA hygiene is correctness
robustness, not traffic; qwen decode sits near its structural collective
floor at this batch/tree size (2 TP-ARs + q-gather + merge/psum ~ 16-27
MB/layer). Next lever recorded: reduce-scatter/all-gather decomposition of
the TP pair, or wider trees to amortize (K, gamma scaling).

**It-3 — grok-1-314b decode_32k: expert parallelism replaces pjit dispatch.**
Diagnosis: 5.8 GB/layer `all-gather(dot)` — the experts dim (8) does not
divide the model axis (16), so the sharding rule was dropped and SPMD
gathered **expert weights** to the tokens every layer; compute was also
inflated 58x. Hypothesis: in our TP layout tokens are replicated across the
model axis, so EP needs *no all_to_all at all*: each rank computes only its
own experts (f-sliced when M % E == 0) and ONE psum([T_loc, d]) ~ 14
MB/layer merges contributions (napkin: collective 14.7 s -> < 1 s given
weights resident). Change: `distributed/ep.py` (shard_map EP,
oracle-validated fwd+grad) wired as the default MoE path under a mesh.
**Measured: compute 3.22 s -> 0.056 s; collective 14.7 s -> 11.3 s.
PARTIALLY CONFIRMED** — the residual 11.3 s is the FSDP(data)-sharded
weights being re-laid-out into the EP arrangement every layer.

**It-4 — grok-1-314b decode_32k: EP with resident (non-FSDP) weights.**
Hypothesis: with MoE weights stored model-axis-resident the per-layer weight
relayout vanishes; expect collective ~ activation psums (~0.05-0.1 s), at
the cost of 39 GB/device weights — OVER the 16 GB v5e HBM, so this run
measures the communication floor; the deployable fix (documented, next
lever) is a 2-D resident layout [experts -> model, d_ff-slices -> data]
with tokens all-gathered across data (119 MB/layer, ~0.45 s/cycle).
**Measured: collective 14.7 s -> 3.05 s, fraction 0.0022 -> 0.0107 (4.9x).
PARTIALLY CONFIRMED** (better than baseline by 4.9x but 30x short of the
napkin floor — the residual is attention/router weight traffic, next
diagnosis target).

**It-5 — stablelm-3b prefill_32k: drop sequence-parallel activations.**
Hypothesis: the remaining 3 x ~600 MB/layer gathers are act_seq(model) <->
heads(model) resharding around attention; disabling SP (activations
replicated, pure heads-TP) removes them, leaving the 2 TP all-reduces
(napkin: 3.8 s -> ~2.6 s; memory rises ~B*S*d bf16 = 335 MB/dev).
**Measured: collective 3.80 s -> 2.55 s (napkin said 2.6), fraction 0.0306
-> 0.0456. CONFIRMED** — though compute rose 0.33 -> 0.60 s (elementwise
work no longer seq-split), a worthwhile trade while collectives dominate.
Cumulative on this cell: fraction 0.0073 -> 0.0456 (6.2x).

**It-6 — grok-1-314b train_4k: EP for training.** The EP path is
differentiable (shard_map + psum transposes to broadcast), so the same fix
applies to MoE train cells, where the baseline pjit dispatch both gathered
expert weights AND inflated compute.
**Measured: compute 1122.7 s -> 17.7 s (63x), collective 1473.6 s -> 48.2 s
(30.6x), roofline fraction 0.0072 -> 0.2191 (30x). CONFIRMED — the largest
single win of the study; 6ND-useful compute now runs at ~22 % of the
512-chip roofline for a 314B MoE.**

### Optimized-cell measurements

| cell | tag | compute_s | collective_s | roofline frac |
|---|---|---|---|---|
"""

HEADER = """\
# EXPERIMENTS — D2SD multi-pod JAX framework

Environment: single-CPU container; TPU v5e is the *target* (197 TFLOP/s
bf16, 819 GB/s HBM, ~50 GB/s/link ICI). Pallas kernels execute under
`interpret=True`; distribution is proven by lowering + compiling against
512 host devices (the multi-pod dry-run). Wall-clock numbers at paper scale
are therefore **roofline-modeled**; acceptance-length (alpha/TPF) numbers
are **measured** by running the real engine on trained small-scale models.

Contents: §Repro (paper tables) · §Dry-run · §Roofline · §Perf.
"""


def main():
    parts = [HEADER]

    bench = ROOT / "bench_output.txt"
    parts.append("\n## §Repro — paper-table reproductions\n")
    parts.append(
        "Measured on the trained small-scale study (see "
        "`repro/training/run_study.py`; target 4L/256d LM on the synthetic "
        "math/code/chat suites, drafters distilled per §3.4; alpha/TPF "
        "measured by running the real engine, speedups roofline-modeled at "
        "paper scale per Eq. 2). Full CSVs: `bench_output.txt`.\n")
    parts.append("""
**Findings vs the paper's claims:**

* **Lossless-ness (core property)**: greedy D2SD output == plain greedy
  target decoding token-for-token with arbitrary drafters, and sampled
  D2SD matches the target distribution to sampling noise (TV ~ 0.02).
  REPRODUCED exactly (tests/test_lossless.py).
* **Fig 2a calibration**: confidence bins track empirical accept rates
  near-diagonally, ECE ~ 0.04. REPRODUCED — the premise of Eq. 4 holds for
  block-diffusion drafters at our scale too.
* **Table 3 ordering**: D2SD > DFlash in BOTH alpha and speedup on every
  task and both temperatures; EAGLE-style AR chain reaches the longest
  alpha (9.1 avg greedy) yet loses wall-clock to its gamma-1 sequential
  drafter passes — the paper's "drafting tax" argument, REPRODUCED
  directionally (our absolute gaps are smaller: a 4M target and 400-step
  drafters sit in a weaker-agreement regime than Qwen3-8B + SpecForge).
* **Table 6 (the key ablation)**: reusing the fixed-anchor DFlash drafter
  as the second drafter yields ZERO alpha gain over single-chain (2.12 ->
  2.12 on math) — the variable-prefix extrapolation failure the paper
  predicts — while the Eq. 6/7-trained VP-Drafter lifts alpha (2.12 ->
  2.40). REPRODUCED cleanly; this isolates the paper's §3.4 contribution.
* **Table 7**: stacking a third VP level leaves alpha ~flat at our scale
  while the modeled speedup regresses (2.16x -> 2.08x) — the paper's
  cost/recovery asymmetry, REPRODUCED directionally.
* **Table 1 (scaling wall)**: TPF saturates with gamma on math
  (1.90/2.03/2.09/2.09 at gamma=4/8/12/16); code is predictable enough
  that gamma=16 has not hit the wall. Partially reproduced (the paper's
  decline at gamma>=24 needs per-gamma retrained drafters, a documented
  deviation).
* **Table 5 DEVIATION**: at our scale, K naive T=1 resamples BEAT the VP
  second draft (math alpha 2.59 vs 2.40). The paper's error-homogeneity
  argument presumes confident drafters whose resamples collapse onto the
  argmax path; our small drafter's categoricals are diffuse, so uniform
  resampling retains diversity. We report this honestly: the cascade
  machinery reproduces, but naive-K's *inferiority* is a property of the
  strong-drafter regime we cannot reach on CPU.
""")
    if bench.exists():
        parts.append("```\n" + bench.read_text()[-8000:] + "\n```\n")
    else:
        parts.append("*(run `python -m benchmarks.run` to regenerate)*\n")

    parts.append("\n## §Dry-run — 10 archs x 4 shapes x 2 meshes\n")
    for mesh in ("single", "multi"):
        parts.append(f"\n### mesh = {mesh} "
                     f"({'2x16x16 = 512 chips' if mesh == 'multi' else '16x16 = 256 chips'})\n")
        parts.append(dryrun_table(mesh))
        parts.append("")

    parts.append("""
Notes:
* `long_500k` is skipped by design for pure full-attention archs (quadratic
  at 524k ctx): qwen2.5, internlm2, gemma2 (global layers), stablelm, kimi,
  grok, llama-vision, whisper. It runs for recurrentgemma-2b + rwkv6-1.6b.
* `argument GB/dev` counts params + optimizer state + caches per device —
  the "fits" proof. kimi-k2 train at 256/512 chips exceeds a single v5e's
  16 GB (a 1T model realistically trains on >= 2k chips); the dry-run
  proves the sharding is coherent, and the bytes scale inversely with mesh
  size.
* FLOPs/collectives come from the loop-aware HLO analyzer (XLA's
  cost_analysis undercounts scan bodies by the trip count — verified and
  documented in `roofline/hlo_analysis.py`; raw cost_analysis flops are
  retained in each JSON for comparison).
""")

    parts.append("\n## §Roofline — per (arch x shape), single pod\n")
    parts.append("""
Terms per assignment: compute = HLO_FLOPs/dev / 197e12; memory =
HBM_bytes/dev / 819e9 (analytic traffic model — fusions hide byte counts
from HLO text; formulas in `roofline/analysis.py`); collective =
collective_bytes/dev / 50e9 (per-op (n-1)/n factors, all-reduce 2x).
`useful` = MODEL_FLOPS (6ND train / 2ND infer, N_active for MoE) over
global HLO FLOPs — the remat/redundancy waste detector. `roofline frac` =
useful-FLOP time / dominant-term time.
""")
    parts.append(roofline_table("single"))
    parts.append("""
Reading the table:
* **Every baseline cell is collective-dominated** — the §Perf iterations
  attack exactly that, cell by cell.
* train cells: useful-ratio ~0.6-0.7 = remat recompute (policy "full"); the
  `dots` policy trades memory for ~1.3x fewer FLOPs (knob:
  `--remat-policy dots`).
* decode cells: useful-ratio ~0.3 reflects tree-verify compute on
  speculative tokens later discarded — the algorithmic price speculation
  pays for latency; alpha converts it back into wall-clock wins.
* One sentence per dominant term is encoded in §Perf's iteration log.
""")

    parts.append("\n## §Perf — hillclimbing log\n")
    parts.append(PERF_NARRATIVE + _opt_cells() + "\n")
    parts.append("""
### Where this lands / beyond-paper deltas

* paper-faithful baseline (D2SD serve_step, naive pjit sharding) is
  recorded per cell above (tags: none);
* beyond-paper optimized versions are recorded under `_opt*` tags —
  separate rows, per the assignment's reproduce-then-optimize contract;
* implemented beyond-paper infrastructure this round: KV-sequence-parallel
  cascade decode (spdecode), replicated-token EP (ep.py), partitionable
  prefill KV writes, blockwise-int8 optimizer moments, int8+error-feedback
  gradient all-reduce, GPipe pod-axis pipeline wrapper, elastic
  checkpoint/restore.
* next levers (napkin-math'd, unimplemented): 2-D resident MoE weight
  layout for >=300B serving (25x on grok decode); reduce-scatter/all-gather
  TP decomposition for decode; ring attention for 32k prefill SP.
""")

    out = ROOT / "EXPERIMENTS.md"
    out.write_text("\n".join(parts))
    print(f"wrote {out} ({out.stat().st_size} bytes)")


if __name__ == "__main__":
    main()
