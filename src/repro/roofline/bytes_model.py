"""Analytic bytes-moved-per-decode-cycle model for the KV read path.

The point of kernelizing the paged read path (``attn_impl="pallas"``) is a
BANDWIDTH claim: per decode cycle, the gather path's HBM traffic scales
with cache *capacity* (``max_pages * page_size`` slots are gathered into a
dense logical view, written back, and re-read by attention regardless of
how much of the cache is live), while the kernel path's traffic scales
with *live* length (the page-table index_map clamps dead logical pages to
the last live one, and Pallas elides repeated-block DMAs — see
``kernels/cascade_attention.cascade_phase1_paged``).

This module prices both paths from config + geometry alone so the serving
bench can emit an attributable ``bytes_model`` section; the companion HLO
attribution (``hlo_analysis.HloModuleStats``: ``gather_bytes`` /
``dynamic_slice_bytes`` of the compiled decode cycle) cross-checks the
shape of the claim on the actual lowering.

Counting rules (deliberately simple, stated so the numbers are auditable):

* Only cache READ traffic is counted — the part the read-path choice
  changes. QKV/MLP matmuls, block KV, tree merge, and commit writes are
  identical across impls and excluded.
* K and V each count once per layer (factor 2).
* "gather" (paged global layers): pool gather read (capacity slots) +
  dense logical-view write (capacity slots) + attention re-read of the
  view (capacity slots) = 3x capacity-sized traffic per layer. This
  matches what XLA materializes for ``kvcache.pool_view`` +
  ``attend_cache_plus_block``.
* "pallas" (paged global layers): ceil(live / page_size) page-sized DMA
  streams per layer — live-length traffic, rounded up to page
  granularity. Per-kv-head-group revisits and split-K re-streaming are
  hardware-scheduling details the model ignores on both paths (they
  multiply both sides equally at fixed geometry).
* ROLLING local layers (dense window-capped buffers, both cache impls):
  "gather" reads the rolling buffer, materializes the [cache; block]
  concat, and re-reads it in attention = 3x window-capped capacity per
  layer; "pallas" streams the buffer ONCE through the dense cascade
  kernel, padded up to the split grid (``ceil(cap / (ns*bk)) * ns*bk``
  with ``ns = min(n_splits, ceil(cap/bk))`` — the padded slots are
  masked dead but still DMA'd). 3x -> ~1x at window scale, NOT
  live-length scaling: every rolling slot is a live candidate.
* ``kv_shards`` > 1 (kv_seq-sharded pools read through the shard_map
  hook, ``distributed/spdecode.sharded_paged_cache_attend`` — verify
  layers AND drafter feature caches): pool payload bytes are sharded
  within each page, so PER-SHARD read traffic is the unsharded figure
  divided by ``kv_shards`` on both impls. The figures reported here are
  per-shard; the fp32 LSE psum that merges shard partials is collective
  (not HBM-read) traffic and is counted by the engine's PAYLOAD_TRACE
  stat, not this model. Rolling local layers are replicated (never
  kv_seq-sharded) and do not divide.
"""
from __future__ import annotations

import math
from typing import Dict

import jax.numpy as jnp


def _esize(dtype) -> int:
    return jnp.dtype(dtype).itemsize


def _global_layers(cfg) -> int:
    return sum(1 for k in cfg.pattern_for_depth() if k == "global")


def _local_layers(cfg) -> int:
    return sum(1 for k in cfg.pattern_for_depth() if k == "local")


def rolling_padded_cap(cap: int, *, n_splits: int = 8, bk: int = 512) -> int:
    """Slots the dense cascade kernel streams for a rolling buffer of
    capacity ``cap``: padded up to the split grid (the padded slots are
    masked dead — ``slot >= cap`` — but still DMA'd). Mirrors
    ``kernels/cascade_attention.cascade_phase1``'s split-count invariant
    ``ns = min(n_splits, ceil(cap/bk))``."""
    ns = max(1, min(n_splits, -(-cap // bk)))
    return -(-cap // (ns * bk)) * (ns * bk)


def target_read_bytes(cfg, *, batch: int, page_size: int, max_pages: int,
                      cache_len: int, impl: str, kv_shards: int = 1,
                      n_splits: int = 8, bk: int = 512) -> Dict[str, float]:
    """Per-cycle cache read bytes of the TARGET: paged global layers
    (per-shard when ``kv_shards`` > 1) plus dense ROLLING local layers
    (window-capped capacity; replicated, never sharded).

    Returns a dict with per-component attribution and a ``total``.
    """
    assert impl in ("gather", "pallas"), impl
    n_l = _global_layers(cfg)
    slot = cfg.num_kv_heads * cfg.head_dim * _esize(cfg.dtype)
    cap_slots = max_pages * page_size
    if impl == "gather":
        per_layer = batch * cap_slots * slot * 2 / kv_shards   # K and V
        comp = {
            "pool_gather_read": float(n_l * per_layer),
            "logical_view_write": float(n_l * per_layer),
            "attend_view_read": float(n_l * per_layer),
        }
    else:
        live_slots = math.ceil(cache_len / page_size) * page_size
        comp = {
            "kernel_page_stream": float(
                n_l * batch * live_slots * slot * 2 / kv_shards),
        }
    n_roll = _local_layers(cfg)
    if n_roll:
        roll_cap = min(max_pages * page_size, cfg.sliding_window)
        per_layer = batch * roll_cap * slot * 2               # K and V
        if impl == "gather":
            comp["rolling_cache_read"] = float(n_roll * per_layer)
            comp["rolling_concat_write"] = float(n_roll * per_layer)
            comp["rolling_attend_read"] = float(n_roll * per_layer)
        else:
            pad = rolling_padded_cap(roll_cap, n_splits=n_splits, bk=bk)
            comp["rolling_kernel_stream"] = float(
                n_roll * batch * pad * slot * 2)
    comp["total"] = float(sum(comp.values()))
    comp["layers"] = n_l + n_roll
    return comp


def drafter_read_bytes(dcfg, *, batch: int, page_size: int, max_pages: int,
                       cache_len: int, impl: str, kv_shards: int = 1,
                       drafts_per_cycle: int = 1) -> Dict[str, float]:
    """Per-cycle paged feature-cache read bytes of ONE drafter.

    Same counting rules as :func:`target_read_bytes`; every drafter layer
    reads the full feature cache (``core/drafter.py`` injects projected
    context K/V at each layer). ``drafts_per_cycle``: how many forward
    passes this drafter runs per decode cycle (the VP second draft runs
    once per branch batch, still one forward).

    ``kv_shards`` > 1: the feature pool is read through the shard_map
    hook (``sharded_paged_cache_attend``) — each shard touches only its
    within-page slice, so per-shard bytes divide by ``kv_shards``; the
    pre-hook behaviour (dense GSPMD ``pool_view`` gather every cycle) is
    the ``kv_shards=1`` gather figure. Note the sharded gather path has
    no once-for-all-layers view: the hook gathers the local slice inside
    every per-layer call, so gather read/write scale with ``layers``.
    """
    assert impl in ("gather", "pallas"), impl
    n_l = dcfg.num_layers
    slot = dcfg.num_kv_heads * dcfg.head_dim * _esize(dcfg.dtype)
    cap_slots = max_pages * page_size
    if impl == "gather":
        # unsharded: pool_view gathers ONCE for all layers
        # (core/drafter.py), then each layer re-reads the dense view;
        # sharded: every layer's shard_map call gathers its local slice
        once = batch * cap_slots * slot * 2 / kv_shards
        gathers = n_l if kv_shards > 1 else 1
        comp = {
            "pool_gather_read": float(drafts_per_cycle * gathers * once),
            "logical_view_write": float(drafts_per_cycle * gathers * once),
            "attend_view_read": float(drafts_per_cycle * n_l * once),
        }
    else:
        live_slots = math.ceil(cache_len / page_size) * page_size
        comp = {
            "kernel_page_stream": float(
                drafts_per_cycle * n_l * batch * live_slots * slot * 2
                / kv_shards),
        }
    comp["total"] = float(sum(comp.values()))
    comp["layers"] = n_l
    return comp


def cycle_read_bytes(tcfg, d1cfg, d2cfg, *, batch: int, page_size: int,
                     max_pages: int, cache_len: int, impl: str,
                     kv_shards: int = 1) -> Dict:
    """Whole-cycle cache read bytes: target verify + both drafters
    (per-shard figures when ``kv_shards`` > 1)."""
    tgt = target_read_bytes(tcfg, batch=batch, page_size=page_size,
                            max_pages=max_pages, cache_len=cache_len,
                            impl=impl, kv_shards=kv_shards)
    d1 = drafter_read_bytes(d1cfg, batch=batch, page_size=page_size,
                            max_pages=max_pages, cache_len=cache_len,
                            impl=impl, kv_shards=kv_shards)
    d2 = drafter_read_bytes(d2cfg, batch=batch, page_size=page_size,
                            max_pages=max_pages, cache_len=cache_len,
                            impl=impl, kv_shards=kv_shards)
    return {
        "impl": impl,
        "batch": batch,
        "page_size": page_size,
        "max_pages": max_pages,
        "cache_len": cache_len,
        "kv_shards": kv_shards,
        "target": tgt,
        "drafter1": d1,
        "drafter2": d2,
        "total": tgt["total"] + d1["total"] + d2["total"],
    }
