"""Analytic bytes-moved-per-decode-cycle model for the KV read path.

The point of kernelizing the paged read path (``attn_impl="pallas"``) is a
BANDWIDTH claim: per decode cycle, the gather path's HBM traffic scales
with cache *capacity* (``max_pages * page_size`` slots are gathered into a
dense logical view, written back, and re-read by attention regardless of
how much of the cache is live), while the kernel path's traffic scales
with *live* length (the page-table index_map clamps dead logical pages to
the last live one, and Pallas elides repeated-block DMAs — see
``kernels/cascade_attention.cascade_phase1_paged``).

This module prices both paths from config + geometry alone so the serving
bench can emit an attributable ``bytes_model`` section; the companion HLO
attribution (``hlo_analysis.HloModuleStats``: ``gather_bytes`` /
``dynamic_slice_bytes`` of the compiled decode cycle) cross-checks the
shape of the claim on the actual lowering.

Counting rules (deliberately simple, stated so the numbers are auditable):

* Only paged-cache READ traffic of global-attention layers is counted —
  the part the read-path choice changes. QKV/MLP matmuls, block KV, tree
  merge, and commit writes are identical across impls and excluded.
* K and V each count once per layer (factor 2).
* "gather": pool gather read (capacity slots) + dense logical-view write
  (capacity slots) + attention re-read of the view (capacity slots) = 3x
  capacity-sized traffic per layer. This matches what XLA materializes
  for ``kvcache.pool_view`` + ``attend_cache_plus_block``.
* "pallas": ceil(live / page_size) page-sized DMA streams per layer —
  live-length traffic, rounded up to page granularity. Per-kv-head-group
  revisits and split-K re-streaming are hardware-scheduling details the
  model ignores on both paths (they multiply both sides equally at fixed
  geometry).
"""
from __future__ import annotations

import math
from typing import Dict

import jax.numpy as jnp


def _esize(dtype) -> int:
    return jnp.dtype(dtype).itemsize


def _global_layers(cfg) -> int:
    return sum(1 for k in cfg.pattern_for_depth() if k == "global")


def target_read_bytes(cfg, *, batch: int, page_size: int, max_pages: int,
                      cache_len: int, impl: str) -> Dict[str, float]:
    """Per-cycle paged-cache read bytes of the TARGET's global layers.

    Returns a dict with per-component attribution and a ``total``.
    """
    assert impl in ("gather", "pallas"), impl
    n_l = _global_layers(cfg)
    slot = cfg.num_kv_heads * cfg.head_dim * _esize(cfg.dtype)
    cap_slots = max_pages * page_size
    if impl == "gather":
        per_layer = batch * cap_slots * slot * 2          # K and V
        comp = {
            "pool_gather_read": float(n_l * per_layer),
            "logical_view_write": float(n_l * per_layer),
            "attend_view_read": float(n_l * per_layer),
        }
    else:
        live_slots = math.ceil(cache_len / page_size) * page_size
        comp = {
            "kernel_page_stream": float(
                n_l * batch * live_slots * slot * 2),
        }
    comp["total"] = float(sum(comp.values()))
    comp["layers"] = n_l
    return comp


def drafter_read_bytes(dcfg, *, batch: int, page_size: int, max_pages: int,
                       cache_len: int, impl: str,
                       drafts_per_cycle: int = 1) -> Dict[str, float]:
    """Per-cycle paged feature-cache read bytes of ONE drafter.

    Same counting rules as :func:`target_read_bytes`; every drafter layer
    reads the full feature cache (``core/drafter.py`` injects projected
    context K/V at each layer). ``drafts_per_cycle``: how many forward
    passes this drafter runs per decode cycle (the VP second draft runs
    once per branch batch, still one forward).
    """
    assert impl in ("gather", "pallas"), impl
    n_l = dcfg.num_layers
    slot = dcfg.num_kv_heads * dcfg.head_dim * _esize(dcfg.dtype)
    cap_slots = max_pages * page_size
    if impl == "gather":
        # pool_view gathers ONCE for all layers (core/drafter.py), then
        # each layer re-reads the dense view
        once = batch * cap_slots * slot * 2
        comp = {
            "pool_gather_read": float(drafts_per_cycle * once),
            "logical_view_write": float(drafts_per_cycle * once),
            "attend_view_read": float(drafts_per_cycle * n_l * once),
        }
    else:
        live_slots = math.ceil(cache_len / page_size) * page_size
        comp = {
            "kernel_page_stream": float(
                drafts_per_cycle * n_l * batch * live_slots * slot * 2),
        }
    comp["total"] = float(sum(comp.values()))
    comp["layers"] = n_l
    return comp


def cycle_read_bytes(tcfg, d1cfg, d2cfg, *, batch: int, page_size: int,
                     max_pages: int, cache_len: int, impl: str) -> Dict:
    """Whole-cycle paged read bytes: target verify + both drafters."""
    tgt = target_read_bytes(tcfg, batch=batch, page_size=page_size,
                            max_pages=max_pages, cache_len=cache_len,
                            impl=impl)
    d1 = drafter_read_bytes(d1cfg, batch=batch, page_size=page_size,
                            max_pages=max_pages, cache_len=cache_len,
                            impl=impl)
    d2 = drafter_read_bytes(d2cfg, batch=batch, page_size=page_size,
                            max_pages=max_pages, cache_len=cache_len,
                            impl=impl)
    return {
        "impl": impl,
        "batch": batch,
        "page_size": page_size,
        "max_pages": max_pages,
        "cache_len": cache_len,
        "target": tgt,
        "drafter1": d1,
        "drafter2": d2,
        "total": tgt["total"] + d1["total"] + d2["total"],
    }
